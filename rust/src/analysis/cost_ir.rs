//! Cost-expression IR: an opt-in capture mode for the cost pipeline.
//!
//! When capture is enabled, every leaf cost (DRAM/SRAM/HB/CXL primitives,
//! `arch/collective.rs` closed forms, `noc/model.rs` tier outputs) and
//! every `OpCost` combinator (`then`/`join`/`repeat`/`replicate` and the
//! fold helpers) records a node in a cost-expression DAG. Each node
//! carries a unit tag ([`Unit`]) and — through its argument expressions
//! ([`SymE`]) — its dependence on the symbolic workload shape variables
//! (batch, seq, kv) as a composition from a *monotone-operation
//! whitelist*: add, multiply (non-negative operands), max, min, ceiling
//! division, floor division (direction-flipping in its divisor), and the
//! power-of-two ceiling. `analysis/prove.rs` runs static passes over the
//! DAG; anything outside the whitelist must be wrapped as
//! [`SymE::Opaque`], which the prover reports with provenance instead of
//! certifying.
//!
//! Two contracts keep the IR honest (both golden-tested):
//!
//! 1. **Capture is strictly opt-in and free when off.** Every tracing
//!    type holds its symbolic side in an `Option<Rc<..>>` that is `None`
//!    unless the entry point seeded symbolic inputs ([`Sh::input`] with a
//!    `Some` capture context). With capture off, no IR is allocated and
//!    the numeric path is the *same* `OpCost` arithmetic as before, in
//!    the same order — `System::run_shape_mapped` stays bit-identical.
//! 2. **Replay is bit-exact.** [`TC`] computes its concrete value by
//!    delegating to the untouched `OpCost` combinators while the node it
//!    records stores the same structure; [`replay`] re-executes the node
//!    tree with those combinators, so point-evaluating the captured IR
//!    reproduces the concrete pipeline's numbers bit-for-bit. The prover
//!    checks this (`prv.eval-drift`) at every cell corner.

use crate::sim::{CostCounts, OpCost};
use std::cell::RefCell;
use std::rc::Rc;

// ------------------------------------------------------------------ units

/// Unit tag carried by every IR value. Cost nodes are `Ns`-valued (their
/// event counts carry per-field `Count`/`Bytes` units, see
/// [`count_unit`]); energy pricing maps `Count`/`Bytes` to `Pj`;
/// repeat/replicate factors are `Dimensionless`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    Ns,
    Count,
    Bytes,
    Pj,
    Dimensionless,
}

impl Unit {
    pub fn label(&self) -> &'static str {
        match self {
            Unit::Ns => "ns",
            Unit::Count => "count",
            Unit::Bytes => "bytes",
            Unit::Pj => "pJ",
            Unit::Dimensionless => "1",
        }
    }
}

/// The declared unit of each `CostCounts` field — the counts half of the
/// unit-consistency story (`CostCounts::fields()` is the name registry;
/// this is the unit registry over the same names).
pub fn count_unit(field: &str) -> Unit {
    match field {
        "hb_bytes" | "gb_bytes" | "cxl_bytes" | "gpu_hbm_bytes" => Unit::Bytes,
        _ => Unit::Count,
    }
}

// -------------------------------------------------------- shape variables

/// The symbolic workload shape variables a proof box ranges over. Decode
/// boxes use `Batch` × `Kv` (the KV length `seq_len` plays); prefill
/// boxes use `Batch` × `Seq`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShapeVar {
    Batch,
    Seq,
    Kv,
}

impl ShapeVar {
    pub const ALL: [ShapeVar; 3] = [ShapeVar::Batch, ShapeVar::Seq, ShapeVar::Kv];

    pub fn index(&self) -> usize {
        match self {
            ShapeVar::Batch => 0,
            ShapeVar::Seq => 1,
            ShapeVar::Kv => 2,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ShapeVar::Batch => "batch",
            ShapeVar::Seq => "seq",
            ShapeVar::Kv => "kv",
        }
    }
}

/// An inclusive per-variable range box `[lo, hi]` (index by
/// [`ShapeVar::index`]). Variables a phase does not use sit at a
/// singleton `[1, 1]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VarBox {
    pub lo: [u64; 3],
    pub hi: [u64; 3],
}

impl VarBox {
    pub fn point(b: u64, s: u64, k: u64) -> VarBox {
        VarBox { lo: [b, s, k], hi: [b, s, k] }
    }
}

// --------------------------------------------------- symbolic expressions

/// A shape expression from the monotone-operation whitelist. All values
/// are non-negative integers, so every constructor is monotone in each
/// argument — non-decreasing except the divisors of `CeilDiv`/`FloorDiv`,
/// which flip direction. [`Opaque`](SymE::Opaque) is the explicit escape
/// hatch for anything else: it evaluates to its recorded value but the
/// prover refuses to certify through it (`prv.whitelist-escape`).
#[derive(Debug, Clone, PartialEq)]
pub enum SymE {
    Const(u64),
    Var(ShapeVar),
    Add(Rc<SymE>, Rc<SymE>),
    Mul(Rc<SymE>, Rc<SymE>),
    CeilDiv(Rc<SymE>, Rc<SymE>),
    FloorDiv(Rc<SymE>, Rc<SymE>),
    Max(Rc<SymE>, Rc<SymE>),
    Min(Rc<SymE>, Rc<SymE>),
    Pow2Ceil(Rc<SymE>),
    Opaque { label: &'static str, value: u64 },
}

impl SymE {
    /// Evaluate at a point (`vals` indexed by [`ShapeVar::index`]).
    pub fn eval(&self, vals: [u64; 3]) -> u64 {
        match self {
            SymE::Const(c) => *c,
            SymE::Var(v) => vals[v.index()],
            SymE::Add(a, b) => a.eval(vals).saturating_add(b.eval(vals)),
            SymE::Mul(a, b) => a.eval(vals).saturating_mul(b.eval(vals)),
            SymE::CeilDiv(a, b) => a.eval(vals).div_ceil(b.eval(vals).max(1)),
            SymE::FloorDiv(a, b) => a.eval(vals) / b.eval(vals).max(1),
            SymE::Max(a, b) => a.eval(vals).max(b.eval(vals)),
            SymE::Min(a, b) => a.eval(vals).min(b.eval(vals)),
            SymE::Pow2Ceil(a) => a.eval(vals).max(1).next_power_of_two(),
            SymE::Opaque { value, .. } => *value,
        }
    }

    /// Sound interval bounds over `bx` via interval arithmetic. Every
    /// whitelist op is monotone in each argument (with the divisor
    /// direction flip), so interval propagation is exact per node.
    /// Returns `None` if an [`SymE::Opaque`] node makes the range
    /// uncertifiable.
    pub fn range(&self, bx: &VarBox) -> Option<(u64, u64)> {
        Some(match self {
            SymE::Const(c) => (*c, *c),
            SymE::Var(v) => (bx.lo[v.index()], bx.hi[v.index()]),
            SymE::Add(a, b) => {
                let (al, ah) = a.range(bx)?;
                let (bl, bh) = b.range(bx)?;
                (al.saturating_add(bl), ah.saturating_add(bh))
            }
            SymE::Mul(a, b) => {
                let (al, ah) = a.range(bx)?;
                let (bl, bh) = b.range(bx)?;
                (al.saturating_mul(bl), ah.saturating_mul(bh))
            }
            SymE::CeilDiv(a, b) => {
                let (al, ah) = a.range(bx)?;
                let (bl, bh) = b.range(bx)?;
                (al.div_ceil(bh.max(1)), ah.div_ceil(bl.max(1)))
            }
            SymE::FloorDiv(a, b) => {
                let (al, ah) = a.range(bx)?;
                let (bl, bh) = b.range(bx)?;
                (al / bh.max(1), ah / bl.max(1))
            }
            SymE::Max(a, b) => {
                let (al, ah) = a.range(bx)?;
                let (bl, bh) = b.range(bx)?;
                (al.max(bl), ah.max(bh))
            }
            SymE::Min(a, b) => {
                let (al, ah) = a.range(bx)?;
                let (bl, bh) = b.range(bx)?;
                (al.min(bl), ah.min(bh))
            }
            SymE::Pow2Ceil(a) => {
                let (al, ah) = a.range(bx)?;
                (al.max(1).next_power_of_two(), ah.max(1).next_power_of_two())
            }
            SymE::Opaque { .. } => return None,
        })
    }

    /// Any [`SymE::Opaque`] node reachable from this expression, with its
    /// label (provenance for `prv.whitelist-escape`).
    pub fn find_opaque(&self) -> Option<&'static str> {
        match self {
            SymE::Const(_) | SymE::Var(_) => None,
            SymE::Add(a, b)
            | SymE::Mul(a, b)
            | SymE::CeilDiv(a, b)
            | SymE::FloorDiv(a, b)
            | SymE::Max(a, b)
            | SymE::Min(a, b) => a.find_opaque().or_else(|| b.find_opaque()),
            SymE::Pow2Ceil(a) => a.find_opaque(),
            SymE::Opaque { label, .. } => Some(label),
        }
    }
}

// ------------------------------------------------------------- directions

/// Direction of an expression/node along one shape variable over a box.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// Provably constant over the box.
    Constant,
    /// Non-decreasing.
    Inc,
    /// Non-increasing.
    Dec,
    /// Could go either way (or an opaque node blocks certification).
    Unknown,
}

impl Dir {
    /// Combine the directions of two monotonically-composed operands.
    pub fn comb(self, o: Dir) -> Dir {
        use Dir::*;
        match (self, o) {
            (Constant, d) | (d, Constant) => d,
            (Inc, Inc) => Inc,
            (Dec, Dec) => Dec,
            _ => Unknown,
        }
    }

    pub fn flip(self) -> Dir {
        match self {
            Dir::Inc => Dir::Dec,
            Dir::Dec => Dir::Inc,
            d => d,
        }
    }

    /// Acceptable for a non-decreasing certificate.
    pub fn non_decreasing(self) -> bool {
        matches!(self, Dir::Constant | Dir::Inc)
    }
}

/// Direction of `e` along `v` over `bx`. A singleton interval refines to
/// `Constant` — this is what resolves products like
/// `pairs * banks_per_pair` (Inc × Dec) once cell subdivision has pinned
/// the decreasing factor's range.
pub fn expr_dir(e: &SymE, v: ShapeVar, bx: &VarBox) -> Dir {
    if let Some((lo, hi)) = e.range(bx) {
        if lo == hi {
            return Dir::Constant;
        }
    }
    match e {
        SymE::Const(_) => Dir::Constant,
        SymE::Var(w) => {
            if *w == v {
                Dir::Inc
            } else {
                Dir::Constant
            }
        }
        SymE::Add(a, b) | SymE::Mul(a, b) | SymE::Max(a, b) | SymE::Min(a, b) => {
            expr_dir(a, v, bx).comb(expr_dir(b, v, bx))
        }
        SymE::CeilDiv(a, b) | SymE::FloorDiv(a, b) => {
            expr_dir(a, v, bx).comb(expr_dir(b, v, bx).flip())
        }
        SymE::Pow2Ceil(a) => expr_dir(a, v, bx),
        SymE::Opaque { .. } => Dir::Unknown,
    }
}

// ------------------------------------------------------------ cost nodes

/// The monotonicity axiom a leaf declares over its arguments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mono {
    /// Latency and every event count are non-decreasing in each argument.
    /// The analytic closed forms and the substrate primitives all satisfy
    /// this (property-tested in `tests/prove.rs`); the calibrated NoC
    /// tier satisfies it *given* a stable correction-factor key, which
    /// the capture records as a guard.
    IncAll,
    /// No axiom (the flit-level simulated tier): the prover reports any
    /// shape-dependent use on a certified path as `prv.non-monotone`.
    Opaque,
}

/// A leaf of the cost DAG: one substrate primitive or closed form, with
/// its symbolic argument expressions and the concrete [`OpCost`] it
/// returned at the captured point.
#[derive(Debug, Clone)]
pub struct LeafNode {
    pub name: &'static str,
    pub args: Vec<Rc<SymE>>,
    pub mono: Mono,
    pub cost: OpCost,
}

/// Node kinds mirror the `OpCost` combinator algebra one-to-one.
#[derive(Debug, Clone)]
pub enum NodeKind {
    Leaf(LeafNode),
    /// Sequential composition: latencies add, counts add.
    Then(Rc<Node>, Rc<Node>),
    /// Parallel composition: latency is the max, counts add.
    Join(Rc<Node>, Rc<Node>),
    /// Serial repetition by the factor expression (concrete value kept
    /// for bit-exact replay).
    Repeat(Rc<Node>, Rc<SymE>, u64),
    /// Parallel replication: same latency, factor× the events.
    Replicate(Rc<Node>, Rc<SymE>, u64),
}

/// One node of the captured cost-expression DAG. Builders always tag
/// cost nodes `Unit::Ns`; the unit-consistency pass re-derives and checks
/// the tags, so a doctored node (or a future builder bug) is caught
/// rather than trusted.
#[derive(Debug, Clone)]
pub struct Node {
    pub unit: Unit,
    pub kind: NodeKind,
}

impl Node {
    pub fn leaf(name: &'static str, args: Vec<Rc<SymE>>, mono: Mono, cost: OpCost) -> Rc<Node> {
        Rc::new(Node { unit: Unit::Ns, kind: NodeKind::Leaf(LeafNode { name, args, mono, cost }) })
    }
}

/// Re-execute the node tree with the plain `OpCost` combinators. Leaves
/// return their stored concrete cost; combinators recompute in the same
/// order the traced pipeline composed them, so the result is bit-exact.
pub fn replay(n: &Node) -> OpCost {
    match &n.kind {
        NodeKind::Leaf(l) => l.cost,
        NodeKind::Then(a, b) => replay(a).then(&replay(b)),
        NodeKind::Join(a, b) => replay(a).join(&replay(b)),
        NodeKind::Repeat(a, _, k) => replay(a).repeat(*k),
        NodeKind::Replicate(a, _, k) => replay(a).replicate(*k),
    }
}

/// Direction of a node's value (latency *and* every event count share the
/// same certificate: `then`/`join`/`repeat`/`replicate` compose both
/// through monotone non-negative operations) along `v` over `bx`.
pub fn node_dir(n: &Node, v: ShapeVar, bx: &VarBox) -> Dir {
    match &n.kind {
        NodeKind::Leaf(l) => {
            let mut d = Dir::Constant;
            for a in &l.args {
                d = d.comb(expr_dir(a, v, bx));
            }
            match l.mono {
                Mono::IncAll => d,
                // no axiom: only a provably shape-independent use is safe
                Mono::Opaque => {
                    if d == Dir::Constant {
                        Dir::Constant
                    } else {
                        Dir::Unknown
                    }
                }
            }
        }
        NodeKind::Then(a, b) | NodeKind::Join(a, b) => {
            node_dir(a, v, bx).comb(node_dir(b, v, bx))
        }
        NodeKind::Repeat(a, k, _) | NodeKind::Replicate(a, k, _) => {
            node_dir(a, v, bx).comb(expr_dir(k, v, bx))
        }
    }
}

// ---------------------------------------------------------- capture mode

/// One shape-dependent control decision the capture observed: branch
/// predicates (`attn.pairs>=banks`) and calibrated-tier correction-factor
/// keys. Every recorded guard is a *monotone* function of the shape
/// variables, so if all corners of a cell agree on the guard vector, the
/// whole cell does — the prover subdivides until they agree.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Guard {
    pub what: &'static str,
    pub val: u64,
}

/// Capture context: seeded at the entry point, threaded explicitly (no
/// globals) through the traced lowering, collecting guards as they are
/// observed.
#[derive(Debug, Default)]
pub struct CaptureCtx {
    guards: RefCell<Vec<Guard>>,
}

impl CaptureCtx {
    pub fn new() -> CaptureCtx {
        CaptureCtx::default()
    }

    pub fn guard(&self, what: &'static str, val: u64) {
        self.guards.borrow_mut().push(Guard { what, val });
    }

    pub fn take_guards(&self) -> Vec<Guard> {
        std::mem::take(&mut self.guards.borrow_mut())
    }
}

/// The capture handle the traced lowering threads: `None` = capture off.
pub type Cap<'a> = Option<&'a CaptureCtx>;

// -------------------------------------------------- shape-tracked values

/// A shape value: the concrete `usize` the pipeline computes with, plus
/// (when capturing) the symbolic expression it came from. All arithmetic
/// delegates the numeric part to the exact `usize` operation the
/// untraced pipeline used, so the value side is bit-identical whether or
/// not an expression rides along.
#[derive(Debug, Clone)]
pub struct Sh {
    pub v: usize,
    pub e: Option<Rc<SymE>>,
}

impl Sh {
    /// A literal (configuration constant or untracked value).
    pub fn lit(v: usize) -> Sh {
        Sh { v, e: None }
    }

    /// A symbolic input: tagged with its shape variable when capturing,
    /// a plain literal otherwise. This is the only place symbols enter —
    /// capture-off runs allocate no expression anywhere downstream.
    pub fn input(cap: Cap, v: usize, var: ShapeVar) -> Sh {
        Sh { v, e: cap.map(|_| Rc::new(SymE::Var(var))) }
    }

    pub fn u64(&self) -> u64 {
        self.v as u64
    }

    /// The expression (materializing a `Const` for literals) — only
    /// called on paths that already allocate.
    pub fn expr(&self) -> Rc<SymE> {
        self.e.clone().unwrap_or_else(|| Rc::new(SymE::Const(self.v as u64)))
    }

    fn bin(&self, o: &Sh, v: usize, f: fn(Rc<SymE>, Rc<SymE>) -> SymE) -> Sh {
        let e = if self.e.is_none() && o.e.is_none() {
            None
        } else {
            Some(Rc::new(f(self.expr(), o.expr())))
        };
        Sh { v, e }
    }

    pub fn add(&self, o: &Sh) -> Sh {
        self.bin(o, self.v + o.v, SymE::Add)
    }

    pub fn mul(&self, o: &Sh) -> Sh {
        self.bin(o, self.v * o.v, SymE::Mul)
    }

    pub fn mulc(&self, k: usize) -> Sh {
        self.mul(&Sh::lit(k))
    }

    pub fn div_ceil(&self, o: &Sh) -> Sh {
        self.bin(o, self.v.div_ceil(o.v.max(1)), SymE::CeilDiv)
    }

    pub fn div_ceilc(&self, k: usize) -> Sh {
        self.div_ceil(&Sh::lit(k))
    }

    pub fn floor_div(&self, o: &Sh) -> Sh {
        self.bin(o, self.v / o.v.max(1), SymE::FloorDiv)
    }

    pub fn max(&self, o: &Sh) -> Sh {
        self.bin(o, self.v.max(o.v), SymE::Max)
    }

    pub fn maxc(&self, k: usize) -> Sh {
        self.max(&Sh::lit(k))
    }

    pub fn min(&self, o: &Sh) -> Sh {
        self.bin(o, self.v.min(o.v), SymE::Min)
    }

    pub fn minc(&self, k: usize) -> Sh {
        self.min(&Sh::lit(k))
    }
}

// ----------------------------------------------------------- traced cost

/// A traced cost: the concrete [`OpCost`] plus (when capturing) its DAG
/// node. The combinators delegate every numeric operation to the
/// untouched `OpCost` methods — same float operations, same order — so
/// the `c` side is bit-identical to the pre-capture pipeline, and the
/// node side replays to exactly `c` (see [`replay`]).
#[derive(Debug, Clone)]
pub struct TC {
    pub c: OpCost,
    pub n: Option<Rc<Node>>,
}

impl TC {
    /// The fold identity (a zero-cost leaf when capturing).
    pub fn zero(cap: Cap) -> TC {
        TC::leaf(cap, "zero", &[], OpCost::zero())
    }

    /// A leaf with the default [`Mono::IncAll`] axiom.
    pub fn leaf(cap: Cap, name: &'static str, args: &[&Sh], c: OpCost) -> TC {
        TC::leaf_m(cap, name, args, Mono::IncAll, c)
    }

    /// A leaf with an explicit monotonicity axiom (the simulated NoC tier
    /// passes [`Mono::Opaque`]).
    pub fn leaf_m(cap: Cap, name: &'static str, args: &[&Sh], mono: Mono, c: OpCost) -> TC {
        let n = cap.map(|_| Node::leaf(name, args.iter().map(|s| s.expr()).collect(), mono, c));
        TC { c, n }
    }

    fn comb(
        &self,
        o: &TC,
        c: OpCost,
        f: fn(Rc<Node>, Rc<Node>) -> NodeKind,
    ) -> TC {
        let n = match (&self.n, &o.n) {
            (Some(a), Some(b)) => {
                Some(Rc::new(Node { unit: Unit::Ns, kind: f(a.clone(), b.clone()) }))
            }
            _ => None,
        };
        TC { c, n }
    }

    pub fn then(&self, o: &TC) -> TC {
        self.comb(o, self.c.then(&o.c), NodeKind::Then)
    }

    pub fn join(&self, o: &TC) -> TC {
        self.comb(o, self.c.join(&o.c), NodeKind::Join)
    }

    fn scaled(&self, k: &Sh, c: OpCost, f: fn(Rc<Node>, Rc<SymE>, u64) -> NodeKind) -> TC {
        let n = self
            .n
            .as_ref()
            .map(|a| Rc::new(Node { unit: Unit::Ns, kind: f(a.clone(), k.expr(), k.u64()) }));
        TC { c, n }
    }

    pub fn repeat(&self, k: &Sh) -> TC {
        self.scaled(k, self.c.repeat(k.u64()), NodeKind::Repeat)
    }

    pub fn replicate(&self, k: &Sh) -> TC {
        self.scaled(k, self.c.replicate(k.u64()), NodeKind::Replicate)
    }

    pub fn serial_all<I: IntoIterator<Item = TC>>(cap: Cap, items: I) -> TC {
        items.into_iter().fold(TC::zero(cap), |a, b| a.then(&b))
    }

    pub fn parallel_all<I: IntoIterator<Item = TC>>(cap: Cap, items: I) -> TC {
        items.into_iter().fold(TC::zero(cap), |a, b| a.join(&b))
    }
}

/// The result of one captured run: the DAG root for the composed phase
/// total (pre-epilogue: all layers + pipeline handoffs), the guard
/// vector, and the concrete totals the IR must replay to bit-for-bit.
#[derive(Debug, Clone)]
pub struct Captured {
    pub root: Rc<Node>,
    pub guards: Vec<Guard>,
    /// Concrete total the traced fold computed (`root` replays to this).
    pub total: OpCost,
    /// `EnergyModel::dynamic(total.counts).total_pj()` at the point.
    pub dynamic_pj: f64,
}

/// Overflow-headroom bound for u64 event counters: the prover requires
/// every leaf count times the product of enclosing repeat/replicate
/// factors to stay under this, leaving two orders of magnitude before
/// wrap (the runtime side saturates + debug-asserts, see `sim/cost.rs`).
pub const COUNT_HEADROOM: u64 = u64::MAX / 256;

#[cfg(test)]
mod tests {
    use super::*;

    fn bx(b: (u64, u64), s: (u64, u64)) -> VarBox {
        VarBox { lo: [b.0, s.0, 1], hi: [b.1, s.1, 1] }
    }

    #[test]
    fn expr_eval_and_range_agree_at_corners() {
        // ceil(seq / max(512/batch, 1)) — the attn else-branch shape
        let batch = Rc::new(SymE::Var(ShapeVar::Batch));
        let seq = Rc::new(SymE::Var(ShapeVar::Seq));
        let bpp = Rc::new(SymE::Max(
            Rc::new(SymE::FloorDiv(Rc::new(SymE::Const(512)), batch)),
            Rc::new(SymE::Const(1)),
        ));
        let tile = SymE::CeilDiv(seq, bpp);
        let b = bx((1, 8), (128, 1024));
        let (lo, hi) = tile.range(&b).unwrap();
        for bv in [1u64, 2, 8] {
            for sv in [128u64, 512, 1024] {
                let v = tile.eval([bv, sv, 1]);
                assert!(lo <= v && v <= hi, "{v} outside [{lo}, {hi}]");
            }
        }
    }

    #[test]
    fn directions_follow_the_whitelist() {
        let b = Rc::new(SymE::Var(ShapeVar::Batch));
        let s = Rc::new(SymE::Var(ShapeVar::Seq));
        let bxx = bx((1, 64), (128, 4096));
        assert_eq!(expr_dir(&SymE::Mul(b.clone(), s.clone()), ShapeVar::Batch, &bxx), Dir::Inc);
        // floor-div flips its divisor
        let inv = SymE::FloorDiv(Rc::new(SymE::Const(512)), b.clone());
        assert_eq!(expr_dir(&inv, ShapeVar::Batch, &bxx), Dir::Dec);
        assert_eq!(expr_dir(&inv, ShapeVar::Seq, &bxx), Dir::Constant);
        // Inc × Dec is Unknown over a wide box...
        let prod = SymE::Mul(b.clone(), Rc::new(inv.clone()));
        assert_eq!(expr_dir(&prod, ShapeVar::Batch, &bxx), Dir::Unknown);
        // ...but refines to Inc once the box pins the Dec factor
        let narrow = bx((257, 512), (128, 4096));
        assert_eq!(SymE::FloorDiv(Rc::new(SymE::Const(512)), b).range(&narrow).unwrap(), (1, 1));
        assert_eq!(expr_dir(&prod, ShapeVar::Batch, &narrow), Dir::Inc);
    }

    #[test]
    fn opaque_blocks_range_and_direction() {
        let o = SymE::Opaque { label: "mystery", value: 7 };
        assert_eq!(o.eval([1, 1, 1]), 7);
        assert!(o.range(&bx((1, 2), (1, 2))).is_none());
        assert_eq!(expr_dir(&o, ShapeVar::Batch, &bx((1, 2), (1, 2))), Dir::Unknown);
        assert_eq!(o.find_opaque(), Some("mystery"));
    }

    #[test]
    fn sh_capture_off_allocates_nothing() {
        let a = Sh::input(None, 8, ShapeVar::Batch);
        let b = a.mulc(16).div_ceilc(512).maxc(1);
        assert!(b.e.is_none());
        assert_eq!(b.v, (8usize * 16).div_ceil(512).max(1));
    }

    #[test]
    fn sh_capture_on_tracks_values_and_exprs() {
        let ctx = CaptureCtx::new();
        let cap: Cap = Some(&ctx);
        let a = Sh::input(cap, 8, ShapeVar::Batch);
        let t = a.mulc(40).div_ceilc(512).maxc(1);
        assert_eq!(t.v, (8 * 40usize).div_ceil(512).max(1));
        let e = t.e.as_ref().expect("expr");
        // the expression evaluates to the same value at the same point
        assert_eq!(e.eval([8, 1, 1]), t.v as u64);
        assert_eq!(e.eval([64, 1, 1]), (64 * 40u64).div_ceil(512).max(1));
    }

    #[test]
    fn tc_capture_off_is_plain_opcost() {
        let c = OpCost { latency_ns: 5.0, counts: CostCounts { dram_mac: 3, ..Default::default() } };
        let t = TC::leaf(None, "x", &[], c);
        assert!(t.n.is_none());
        let r = t.repeat(&Sh::lit(4)).then(&TC::leaf(None, "y", &[], c));
        assert!(r.n.is_none());
        let plain = c.repeat(4).then(&c);
        assert_eq!(r.c.latency_ns.to_bits(), plain.latency_ns.to_bits());
        assert_eq!(r.c.counts, plain.counts);
    }

    #[test]
    fn replay_is_bit_exact() {
        let ctx = CaptureCtx::new();
        let cap: Cap = Some(&ctx);
        let k = Sh::input(cap, 3, ShapeVar::Batch);
        let a = TC::leaf(
            cap,
            "a",
            &[&k],
            OpCost { latency_ns: 1.25, counts: CostCounts { hb_bytes: 7, ..Default::default() } },
        );
        let b = TC::leaf(cap, "b", &[], OpCost::latency(0.75));
        let total = a.repeat(&k).join(&b).then(&a).replicate(&Sh::lit(16));
        let r = replay(total.n.as_ref().unwrap());
        assert_eq!(r.latency_ns.to_bits(), total.c.latency_ns.to_bits());
        assert_eq!(r.counts, total.c.counts);
    }

    #[test]
    fn node_dir_composes_through_combinators() {
        let ctx = CaptureCtx::new();
        let cap: Cap = Some(&ctx);
        let b = Sh::input(cap, 4, ShapeVar::Batch);
        let leafy = TC::leaf(cap, "l", &[&b], OpCost::latency(1.0));
        let total = leafy.repeat(&b.mulc(2)).then(&TC::leaf(cap, "k", &[], OpCost::latency(2.0)));
        let n = total.n.unwrap();
        let wide = bx((1, 64), (1, 1));
        assert_eq!(node_dir(&n, ShapeVar::Batch, &wide), Dir::Inc);
        assert_eq!(node_dir(&n, ShapeVar::Seq, &wide), Dir::Constant);
        // an opaque leaf with shape-dependent args cannot be certified
        let op = TC::leaf_m(cap, "sim", &[&b], Mono::Opaque, OpCost::latency(1.0));
        assert_eq!(node_dir(op.n.as_ref().unwrap(), ShapeVar::Batch, &wide), Dir::Unknown);
    }

    #[test]
    fn guards_record_in_order() {
        let ctx = CaptureCtx::new();
        ctx.guard("attn.pairs>=banks", 1);
        ctx.guard("noc.reduce.factor-key", 8);
        let g = ctx.take_guards();
        assert_eq!(g.len(), 2);
        assert_eq!(g[0], Guard { what: "attn.pairs>=banks", val: 1 });
        assert_eq!(g[1].val, 8);
    }

    #[test]
    fn count_units_cover_every_field() {
        for (name, _) in CostCounts::default().fields() {
            let u = count_unit(name);
            assert!(matches!(u, Unit::Count | Unit::Bytes), "{name} has unit {u:?}");
        }
        assert_eq!(count_unit("hb_bytes"), Unit::Bytes);
        assert_eq!(count_unit("dram_mac"), Unit::Count);
    }
}
