//! Static linter for Row-Level ISA programs (paper Table 1).
//!
//! Checks a [`RowProgram`] without executing it: address/bank bounds,
//! mask/len consistency, def-before-use and dead stores per bank address
//! range, the fused-chain legality rules (`lane_width` vs mesh columns,
//! ALU-binding conflicts, divider occupancy), SRAM gang ordering and
//! capacity — plus a count cross-check that derives flit/op totals from
//! the `plan()` output and flags drift against the analytic
//! `arch/collective.rs` closed forms (the same contract the NoC
//! calibration gate enforces dynamically).

use crate::arch::collective::noc_exp;
use crate::config::{HwConfig, SramGang};
use crate::isa::interp::BANK_MEM_ELEMS;
use crate::isa::row::{AccessDir, ArgSrc, ExchangeMode, RowInst, RowProgram, ALL_BANKS};
use crate::isa::translate::{plan, FusedChain, Plan};

use super::{CheckReport, Diag};

/// What the linter may assume about bank memory before the program runs.
#[derive(Debug, Clone, Default)]
pub struct LintOptions {
    /// Address ranges `(addr, len)` initialized externally (via
    /// `Machine::write_row`) before the program executes.
    pub inputs: Vec<(usize, usize)>,
    /// Skip the def-before-use / dead-store passes entirely. The
    /// `Machine::run` debug hook uses this: callers may have written any
    /// row, so flow facts about initial memory are unknowable there.
    pub assume_all_initialized: bool,
    /// Plan chains with path-generation fusion on (the default; matches
    /// how the program will actually be translated).
    pub fuse: bool,
}

impl LintOptions {
    /// Lint with a declared set of externally initialized input rows.
    pub fn with_inputs(inputs: Vec<(usize, usize)>) -> LintOptions {
        LintOptions { inputs, assume_all_initialized: false, fuse: true }
    }

    /// Lint structural properties only (the `Machine::run` hook).
    pub fn assume_initialized() -> LintOptions {
        LintOptions { inputs: Vec::new(), assume_all_initialized: true, fuse: true }
    }
}

/// One recorded store, for the def-use / dead-store pass.
struct WriteRec {
    lo: usize,
    hi: usize,
    mask: u64,
    idx: usize,
    what: &'static str,
    read: bool,
}

/// Flow state threaded through the program-order pass.
struct Flow<'a> {
    writes: Vec<WriteRec>,
    inputs: &'a [(usize, usize)],
    enabled: bool,
}

impl Flow<'_> {
    /// A read of `[lo, hi)` on `mask` banks: marks overlapping stores
    /// live and reports when some part of the range has no earlier store
    /// (or declared input) covering every read bank.
    fn read(&mut self, rep: &mut CheckReport, ctx: &str, lo: usize, hi: usize, mask: u64) {
        if !self.enabled || lo >= hi {
            return;
        }
        let mut cover: Vec<(usize, usize)> =
            self.inputs.iter().map(|&(a, l)| (a, a + l)).collect();
        for w in self.writes.iter_mut() {
            if w.lo < hi && lo < w.hi && w.mask & mask != 0 {
                w.read = true;
            }
            // only stores present on *every* read bank define the value
            if w.mask & mask == mask {
                cover.push((w.lo, w.hi));
            }
        }
        cover.sort_unstable();
        let mut at = lo;
        for (a, b) in cover {
            if a > at {
                break;
            }
            at = at.max(b);
            if at >= hi {
                return;
            }
        }
        rep.push(Diag::warning(
            "isa.use-before-def",
            ctx,
            format!(
                "reads [{at}, {hi}) before any instruction or declared input writes it \
                 (fresh DRAM reads as zeros)"
            ),
        ));
    }

    /// A store of `[lo, hi)` on `mask` banks: reports earlier stores it
    /// fully shadows that were never read in between.
    fn write(
        &mut self,
        rep: &mut CheckReport,
        lo: usize,
        hi: usize,
        mask: u64,
        idx: usize,
        what: &'static str,
    ) {
        if !self.enabled || lo >= hi {
            return;
        }
        for w in self.writes.iter_mut() {
            if !w.read && w.lo >= lo && w.hi <= hi && w.mask & !mask == 0 {
                rep.push(Diag::warning(
                    "isa.dead-store",
                    format!("inst {} ({})", w.idx, w.what),
                    format!(
                        "store to [{}, {}) is fully overwritten by inst {idx} before any read",
                        w.lo, w.hi
                    ),
                ));
                w.read = true; // report a shadowed store once
            }
        }
        self.writes.push(WriteRec { lo, hi, mask, idx, what, read: false });
    }
}

fn inst_name(i: &RowInst) -> &'static str {
    match i {
        RowInst::NocScalar { .. } => "NoC_Scalar",
        RowInst::NocAccess { .. } => "NoC_Access",
        RowInst::NocBCast { .. } => "NoC_BCast",
        RowInst::NocReduce { .. } => "NoC_Reduce",
        RowInst::NocExchange { .. } => "NoC_Exchange",
        RowInst::SramWrite { .. } => "SRAM_Write",
        RowInst::SramCompute { .. } => "SRAM_Compute",
        RowInst::DramGemv { .. } => "DRAM_GeMV",
        RowInst::Fill { .. } => "Fill",
    }
}

/// Bounds helper: `[addr, addr+len)` must fit the bank memory model.
fn check_range(rep: &mut CheckReport, ctx: &str, what: &str, addr: usize, len: usize) {
    let end = addr.saturating_add(len);
    if end > BANK_MEM_ELEMS {
        rep.push(Diag::error(
            "isa.addr-bounds",
            ctx,
            format!("{what} [{addr}, {end}) exceeds the {BANK_MEM_ELEMS}-element bank memory"),
        ));
    }
}

/// Lint one Row-Level program against a hardware config and gang shape.
/// Pure: no interpreter state is touched. The report is normalized
/// (sorted, deduplicated) before returning.
pub fn lint(prog: &RowProgram, hw: &HwConfig, gang: SramGang, opts: &LintOptions) -> CheckReport {
    let mut rep = CheckReport::default();
    let banks = hw.dram.banks_per_channel;
    let (gi, go) = gang.shape(&hw.sram);
    let mut flow =
        Flow { writes: Vec::new(), inputs: &opts.inputs, enabled: !opts.assume_all_initialized };
    let mut sram_loaded: u64 = 0;

    for (idx, inst) in prog.insts.iter().enumerate() {
        let ctx = format!("inst {idx} ({})", inst_name(inst));
        let mask = inst.mask();
        if mask == 0 {
            rep.push(Diag::warning("isa.mask-empty", &ctx, "bank mask is empty: the instruction runs on no bank".to_string()));
        }
        if banks < u64::BITS as usize && mask >> banks != 0 {
            rep.push(Diag::error(
                "isa.mask-range",
                &ctx,
                format!("mask {mask:#x} selects banks beyond the channel's {banks}"),
            ));
        }
        match inst {
            RowInst::NocScalar { src, dst, len, arg, .. } => {
                lint_len(&mut rep, &ctx, *len);
                check_range(&mut rep, &ctx, "src", *src, *len);
                check_range(&mut rep, &ctx, "dst", *dst, *len);
                flow.read(&mut rep, &ctx, *src, src + len, mask);
                if let ArgSrc::Row(r) = arg {
                    check_range(&mut rep, &ctx, "arg row", *r, *len);
                    flow.read(&mut rep, &ctx, *r, r + len, mask);
                }
                flow.write(&mut rep, *dst, dst + len, mask, idx, inst_name(inst));
            }
            RowInst::Fill { dst, len, .. } => {
                lint_len(&mut rep, &ctx, *len);
                check_range(&mut rep, &ctx, "dst", *dst, *len);
                flow.write(&mut rep, *dst, dst + len, mask, idx, inst_name(inst));
            }
            RowInst::NocAccess { dir, addr, .. } => {
                if *dir == AccessDir::Rd {
                    check_range(&mut rep, &ctx, "dst", *addr, 1);
                    flow.write(&mut rep, *addr, addr + 1, mask, idx, inst_name(inst));
                }
            }
            RowInst::NocBCast { src, dst, src_bank, len, .. } => {
                lint_len(&mut rep, &ctx, *len);
                check_range(&mut rep, &ctx, "src", *src, *len);
                check_range(&mut rep, &ctx, "dst", *dst, *len);
                if *src_bank >= banks {
                    rep.push(Diag::error(
                        "isa.mask-range",
                        &ctx,
                        format!("src_bank {src_bank} outside the channel's {banks} banks"),
                    ));
                } else {
                    flow.read(&mut rep, &ctx, *src, src + len, 1 << src_bank);
                }
                flow.write(&mut rep, *dst, dst + len, mask | (1 << src_bank), idx, inst_name(inst));
            }
            RowInst::NocReduce { src, dst, dst_bank, len, .. } => {
                lint_len(&mut rep, &ctx, *len);
                check_range(&mut rep, &ctx, "src", *src, *len);
                check_range(&mut rep, &ctx, "dst", *dst, *len);
                if *dst_bank >= banks {
                    rep.push(Diag::error(
                        "isa.mask-range",
                        &ctx,
                        format!("dst_bank {dst_bank} outside the channel's {banks} banks"),
                    ));
                }
                flow.read(&mut rep, &ctx, *src, src + len, mask);
                flow.write(&mut rep, *dst, dst + len, 1u64 << (*dst_bank).min(63), idx, inst_name(inst));
            }
            RowInst::NocExchange { mode, src, dst, offset, group, len, .. } => {
                lint_len(&mut rep, &ctx, *len);
                check_range(&mut rep, &ctx, "src", *src, *len);
                check_range(&mut rep, &ctx, "dst", *dst, *len);
                match mode {
                    ExchangeMode::RPlus | ExchangeMode::RMinus => {
                        if (*offset, *group) != (1, 2) {
                            rep.push(Diag::error(
                                "isa.exchange-shape",
                                &ctx,
                                format!(
                                    "R-mode exchange supports only the pair swap \
                                     (offset 1, group 2), got ({offset}, {group})"
                                ),
                            ));
                        }
                    }
                    ExchangeMode::TPlus | ExchangeMode::TMinus => {
                        if *group == 0 || *group > banks {
                            rep.push(Diag::error(
                                "isa.exchange-shape",
                                &ctx,
                                format!("T-mode group {group} invalid for a {banks}-bank channel"),
                            ));
                        } else if *offset % *group == 0 {
                            rep.push(Diag::warning(
                                "isa.exchange-shape",
                                &ctx,
                                format!("offset {offset} ≡ 0 mod group {group}: every bank swaps with itself"),
                            ));
                        }
                    }
                }
                flow.read(&mut rep, &ctx, *src, src + len, mask);
                flow.write(&mut rep, *dst, dst + len, mask, idx, inst_name(inst));
            }
            RowInst::SramWrite { addr, len, .. } => {
                lint_len(&mut rep, &ctx, *len);
                check_range(&mut rep, &ctx, "weights", *addr, *len);
                if *len > gi * go {
                    rep.push(Diag::error(
                        "isa.sram-capacity",
                        &ctx,
                        format!("loads {len} weights into a {go}x{gi} gang ({} max)", gi * go),
                    ));
                }
                flow.read(&mut rep, &ctx, *addr, addr + len, mask);
                sram_loaded |= mask;
            }
            RowInst::SramCompute { src, dst, len, .. } => {
                lint_len(&mut rep, &ctx, *len);
                check_range(&mut rep, &ctx, "src", *src, *len);
                check_range(&mut rep, &ctx, "dst", *dst, 1);
                if mask & !sram_loaded != 0 {
                    rep.push(Diag::error(
                        "isa.sram-order",
                        &ctx,
                        format!(
                            "SRAM_Compute before SRAM_Write: banks {:#x} have no loaded gang weights",
                            mask & !sram_loaded
                        ),
                    ));
                }
                flow.read(&mut rep, &ctx, *src, src + len, mask);
                flow.write(&mut rep, *dst, dst + 1, mask, idx, inst_name(inst));
            }
            RowInst::DramGemv { w, src, dst, out_dim, in_dim, .. } => {
                lint_len(&mut rep, &ctx, out_dim * in_dim);
                check_range(&mut rep, &ctx, "weights", *w, out_dim * in_dim);
                check_range(&mut rep, &ctx, "src", *src, *in_dim);
                check_range(&mut rep, &ctx, "dst", *dst, *out_dim);
                flow.read(&mut rep, &ctx, *w, w + out_dim * in_dim, mask);
                flow.read(&mut rep, &ctx, *src, src + in_dim, mask);
                flow.write(&mut rep, *dst, dst + out_dim, mask, idx, inst_name(inst));
            }
        }
    }

    // Chain-level checks on the translated plan.
    for (pi, p) in plan(&prog.insts, opts.fuse).iter().enumerate() {
        if let Plan::Chain(c) = p {
            let ctx = format!("chain {pi} ({} steps, iter {})", c.steps.len(), c.iter_num);
            if c.lane_width() > hw.noc.mesh_cols {
                rep.push(Diag::error(
                    "isa.lane-overflow",
                    &ctx,
                    format!(
                        "chain needs {} router columns but the mesh has {}: \
                         column assignments wrap and collide",
                        c.lane_width(),
                        hw.noc.mesh_cols
                    ),
                ));
            }
            if c.has_alu_conflict() {
                rep.push(Diag::warning(
                    "isa.alu-conflict",
                    &ctx,
                    "two steps bind the same ALU class with different args; \
                     each such pair costs an extra column"
                        .to_string(),
                ));
            }
            if c.div_steps() >= 2 {
                rep.push(Diag::warning(
                    "isa.div-occupancy",
                    &ctx,
                    format!(
                        "{} Div steps serialize on the bank's iterative divider \
                         ({} cycles each)",
                        c.div_steps(),
                        hw.noc.div_cycles
                    ),
                ));
            }
        }
    }

    rep.normalize();
    rep
}

fn lint_len(rep: &mut CheckReport, ctx: &str, len: usize) {
    if len == 0 {
        rep.push(Diag::warning("isa.len-zero", ctx, "zero-length operation does nothing".to_string()));
    }
}

/// Per-element static counts of one fused chain, as the flit-level
/// machine bills them: ALU ops = (steps + iter-tagged steps) × IterNum
/// (iterating steps also update their ArgReg each traversal); flit hops
/// = one column per lane-width slot per traversal, plus the inject and
/// deliver hops at the chain endpoints.
pub fn chain_static_counts(c: &FusedChain) -> (u64, u64) {
    let iter_steps = c.steps.iter().filter(|(_, _, it, _, _)| *it).count() as u64;
    let alu = (c.steps.len() as u64 + iter_steps) * c.iter_num as u64;
    let hops = c.lane_width() as u64 * c.iter_num as u64 + 2;
    (alu, hops)
}

/// Derive the exp kernel's flit/op totals statically from its `plan()`
/// and cross-check them against the analytic `noc_exp` closed form.
/// Drift beyond `tol` (relative) means the Row-Level program and the
/// formula the cost model bills have diverged — the static mirror of
/// the dynamic calibration gate.
pub fn exp_count_crosscheck(len: usize, rounds: u32, hw: &HwConfig, tol: f64) -> CheckReport {
    let mut rep = CheckReport::default();
    let prog = RowProgram::exp_program(0, 4096, len, rounds, ALL_BANKS);
    let (mut alu_pe, mut hops_pe) = (0u64, 0u64);
    for p in &plan(&prog.insts, true) {
        if let Plan::Chain(c) = p {
            let (a, h) = chain_static_counts(c);
            alu_pe += a;
            hops_pe += h;
        }
    }
    let derived_alu = alu_pe * len as u64;
    let derived_hops = hops_pe * len as u64;
    let formula = noc_exp(len as u64, rounds as u64, &hw.noc);
    let pairs = [
        ("noc_alu_ops", derived_alu, formula.counts.noc_alu_ops),
        ("noc_flit_hops", derived_hops, formula.counts.noc_flit_hops),
    ];
    for (name, derived, analytic) in pairs {
        let drift = if analytic == 0 {
            if derived == 0 { 0.0 } else { f64::INFINITY }
        } else {
            (derived as f64 - analytic as f64).abs() / analytic as f64
        };
        if drift > tol {
            rep.push(Diag::error(
                "isa.count-drift",
                format!("exp(len {len}, rounds {rounds}) {name}"),
                format!(
                    "statically derived {derived} vs analytic {analytic} \
                     ({:.0}% drift, tolerance {:.0}%)",
                    drift * 100.0,
                    tol * 100.0
                ),
            ));
        }
    }
    rep.normalize();
    rep
}
