//! `compair audit`: semantic invariants over the cost pipeline.
//!
//! `compair check` (PR 8) verifies *structure* — ISA legality, placement
//! legality, config consistency. This second tier verifies the
//! *semantics* of the numbers the whole stack is built on: every report
//! produced on a deterministic pow2 lattice of
//! (arch × model × phase × shape × NoC-fidelity × mapping-mode) points
//! ([`super::audit_lattice`]) must obey the physics the simulators claim
//! to model. Violations surface as `aud.*` diagnostics through the same
//! [`Diag`]/[`CheckReport`] framework, so the CLI, `Engine::audit`, the
//! CI gate, and the negative corpus in `tests/audit.rs` all speak one
//! language.
//!
//! The invariant catalog, one registered code each:
//!
//! * **`aud.non-finite` / `aud.negative` / `aud.unit-range`** — every
//!   latency/energy/throughput field is a finite, non-negative number;
//!   fractions, utilizations, and SLO attainments stay in `[0, 1]`, and a
//!   class that completed nothing reports exactly 0.0 attainment.
//! * **`aud.op-conservation`** — the per-op costs in a [`PhaseReport`]
//!   re-compose (same fold, same pipeline/handoff arithmetic as
//!   `System::run_shape_mapped`) to the layer cost, total latency, and
//!   throughput the report claims.
//! * **`aud.energy-conservation`** — re-pricing the re-composed counts
//!   through a fresh [`EnergyModel`] reproduces every component of the
//!   report's [`EnergyBreakdown`](crate::energy::EnergyBreakdown).
//! * **`aud.bytes-conservation`** — the `arch/collective` closed forms
//!   move exactly the bytes/events they are handed (nothing vanishes,
//!   nothing is conjured), degenerate shapes price to exactly zero, and
//!   the cluster KV-migration path bills exactly `migration_bytes` at the
//!   CXL rate.
//! * **`aud.monotonic`** — latency and dynamic energy never decrease
//!   along pow2 batch/seq/KV chains at fixed everything-else.
//! * **`aud.cache-coherence`** — a memoizing model answers bit-identically
//!   to the uncached reference, and repeat queries are stable.
//! * **`aud.never-lose`** — the auto-mapper never scores worse than the
//!   static mapping, re-proven from the audit side.
//! * **`aud.fidelity-band`** — every calibration anchor's calibrated
//!   residual is inside the gated 20% band of the simulator; the raw
//!   analytic ratio outside its documented 0.5–2.0× band, or a
//!   volume-ordering disagreement between tiers, warns.
//! * **`aud.calibration-bounds`** — every fitted NoC correction factor is
//!   finite and inside [`FACTOR_BOUNDS`](crate::noc::FACTOR_BOUNDS).
//!
//! Every check is a pure function of fabricatable inputs (reports, priced
//! costs, anchor rows), so the seeded-defect corpus can hand each one a
//! single doctored artifact and prove the code fires.

use std::collections::BTreeMap;

use crate::analysis::{CheckReport, Diag};
use crate::arch::collective as coll;
use crate::arch::{attacc, AttAccConfig, CachedCostModel, CostModel, PhaseReport, System};
use crate::config::{ArchKind, HwConfig, MappingMode, Phase, RunConfig};
use crate::coordinator::{
    Cluster, ClusterConfig, ClusterReport, RouterPolicy, ServeConfig, ServeReport, Server,
};
use crate::energy::EnergyModel;
use crate::mapper::AutoMappedCostModel;
use crate::noc::{calibration_factors, calibration_report, CalibAnchor, FACTOR_BOUNDS};
use crate::sim::{CostCounts, OpCost};

use super::audit_lattice::{self as lattice, AuditPoint, ShapeAnchor};

/// Relative tolerance for re-derived f64 identities. The audit re-runs
/// the *same* arithmetic the simulator ran, so agreement is bit-exact in
/// practice; the epsilon only absorbs hypothetical re-association.
const REL_TOL: f64 = 1e-9;

/// The calibrated tier's gated residual band vs the simulator — the same
/// 20% contract ci.sh and `tests/prop_invariants.rs` enforce.
const FIDELITY_BAND: f64 = 0.2;

/// Documented band of the raw analytic/simulator ratio; escaping it is a
/// warning (the calibration exists to close exactly this gap).
const RAW_RATIO_BAND: (f64, f64) = (0.5, 2.0);

/// Audit knobs (CLI `--deep` widens the lattice and chains).
#[derive(Debug, Clone, Copy, Default)]
pub struct AuditOptions {
    pub deep: bool,
}

// ------------------------------------------------------------ primitives

fn num(rep: &mut CheckReport, ctx: &str, name: &str, v: f64) {
    if !v.is_finite() {
        rep.push(Diag::error("aud.non-finite", ctx, format!("{name} is {v} (not finite)")));
    } else if v < 0.0 {
        rep.push(Diag::error("aud.negative", ctx, format!("{name} is negative ({v:.6})")));
    }
}

fn unit(rep: &mut CheckReport, ctx: &str, name: &str, v: f64) {
    num(rep, ctx, name, v);
    if v.is_finite() && !(0.0..=1.0).contains(&v) {
        rep.push(Diag::error("aud.unit-range", ctx, format!("{name} = {v:.6} outside [0, 1]")));
    }
}

/// First counter two count vectors disagree on, for precise messages.
fn first_count_diff(a: &CostCounts, b: &CostCounts) -> Option<(&'static str, u64, u64)> {
    a.fields()
        .iter()
        .zip(b.fields().iter())
        .find(|((_, x), (_, y))| x != y)
        .map(|(&(n, x), &(_, y))| (n, x, y))
}

/// One closed-form traffic identity: the priced cost must carry exactly
/// `want` events on `counter`. Public so the negative corpus can hand in
/// a doctored count next to the real closed-form outputs the audit feeds.
pub fn check_counter(
    rep: &mut CheckReport,
    ctx: &str,
    counter: &'static str,
    got: u64,
    want: u64,
) {
    if got != want {
        rep.push(Diag::error(
            "aud.bytes-conservation",
            ctx,
            format!("{counter} carries {got} events, the closed form conserves {want}"),
        ));
    }
}

/// One fitted calibration factor must be finite and inside the declared
/// [`FACTOR_BOUNDS`]. Public for the corpus.
pub fn check_factor(rep: &mut CheckReport, collective: &str, key: u64, factor: f64) {
    let ctx = format!("{collective} key={key}");
    if !factor.is_finite() {
        rep.push(Diag::error(
            "aud.calibration-bounds",
            ctx,
            format!("fitted factor is {factor} (not finite)"),
        ));
    } else if factor < FACTOR_BOUNDS.0 || factor > FACTOR_BOUNDS.1 {
        rep.push(Diag::error(
            "aud.calibration-bounds",
            ctx,
            format!(
                "fitted factor {factor:.4} outside declared bounds [{}, {}]",
                FACTOR_BOUNDS.0, FACTOR_BOUNDS.1
            ),
        ));
    }
}

// ------------------------------------------------------- report sanity

/// Finiteness / non-negativity / unit-range over every numeric field a
/// [`PhaseReport`] carries (per-op latencies included; event counts are
/// `u64` and cannot misbehave by type).
pub fn check_phase_sanity(ctx: &str, r: &PhaseReport) -> CheckReport {
    let mut rep = CheckReport::default();
    num(&mut rep, ctx, "latency_ns", r.latency_ns);
    num(&mut rep, ctx, "throughput_tok_s", r.throughput_tok_s);
    num(&mut rep, ctx, "layer_cost.latency_ns", r.layer_cost.latency_ns);
    for (name, pj) in r.energy.components() {
        num(&mut rep, ctx, &format!("energy.{name}"), pj);
    }
    num(&mut rep, ctx, "energy.total_pj", r.energy.total_pj());
    unit(&mut rep, ctx, "nonlinear_frac", r.nonlinear_frac);
    unit(&mut rep, ctx, "collective_frac", r.collective_frac);
    unit(&mut rep, ctx, "bank_util", r.bank_util);
    for op in &r.ops {
        num(&mut rep, ctx, &format!("op {}.latency_ns", op.name), op.cost.latency_ns);
    }
    rep.normalize();
    rep
}

/// The shared serve-report validator: the predicate the serving/cluster
/// tests and `compair audit` both enforce (this is the deduplicated form
/// of the ad-hoc finiteness asserts the coordinator tests used to carry).
pub fn check_serve_report(ctx: &str, r: &ServeReport) -> CheckReport {
    let mut rep = CheckReport::default();
    num(&mut rep, ctx, "throughput_tok_s", r.throughput_tok_s);
    num(&mut rep, ctx, "energy_per_token_pj", r.energy_per_token_pj);
    for (name, v) in [
        ("ttft_p50_ns", r.ttft_p50_ns),
        ("ttft_p99_ns", r.ttft_p99_ns),
        ("tpot_p50_ns", r.tpot_p50_ns),
        ("tpot_p99_ns", r.tpot_p99_ns),
        ("req_latency_p50_ns", r.req_latency_p50_ns),
        ("req_latency_p99_ns", r.req_latency_p99_ns),
    ] {
        num(&mut rep, ctx, name, v);
    }
    unit(&mut rep, ctx, "slo_attainment", r.slo_attainment);
    for (name, pj) in r.energy.components() {
        num(&mut rep, ctx, &format!("energy.{name}"), pj);
    }
    for c in &r.per_class {
        let cctx = format!("{ctx}/{}", c.class);
        for (name, v) in [
            ("ttft_p50_ns", c.ttft_p50_ns),
            ("ttft_p99_ns", c.ttft_p99_ns),
            ("tpot_p50_ns", c.tpot_p50_ns),
            ("tpot_p99_ns", c.tpot_p99_ns),
        ] {
            num(&mut rep, &cctx, name, v);
        }
        for (name, v) in [
            ("ttft_attainment", c.ttft_attainment),
            ("tpot_attainment", c.tpot_attainment),
            ("slo_attainment", c.slo_attainment),
        ] {
            unit(&mut rep, &cctx, name, v);
            if c.completed == 0 && v.abs() > 1e-12 {
                rep.push(Diag::error(
                    "aud.unit-range",
                    cctx.clone(),
                    format!("{name} = {v:.6} with zero completed requests (must be 0.0)"),
                ));
            }
        }
    }
    rep.normalize();
    rep
}

// ------------------------------------------------------- conservation

/// Per-op → phase conservation and independent energy re-pricing. The
/// re-composition mirrors `System::run_shape_mapped` exactly: fold the
/// per-op costs in order, repeat over layers, append the pipeline
/// handoff, then re-price the total counts through a fresh
/// [`EnergyModel`] built from the same hardware point.
pub fn check_phase_conservation(
    ctx: &str,
    r: &PhaseReport,
    rc: &RunConfig,
    phase: Phase,
    batch: usize,
    seq_len: usize,
) -> CheckReport {
    let mut rep = CheckReport::default();

    // (1) the ops must fold to the layer cost the report claims
    let mut layer = OpCost::zero();
    let mut nl_ns = 0.0;
    let mut coll_ns = 0.0;
    for op in &r.ops {
        match op.class {
            crate::workload::OpClass::NonLinear => nl_ns += op.cost.latency_ns,
            crate::workload::OpClass::Collective => coll_ns += op.cost.latency_ns,
            _ => {}
        }
        layer = layer.then(&op.cost);
    }
    if layer.latency_ns.to_bits() != r.layer_cost.latency_ns.to_bits() {
        rep.push(Diag::error(
            "aud.op-conservation",
            ctx,
            format!(
                "per-op latencies sum to {:.6} ns but layer_cost claims {:.6} ns",
                layer.latency_ns, r.layer_cost.latency_ns
            ),
        ));
    }
    if let Some((name, got, want)) = first_count_diff(&r.layer_cost.counts, &layer.counts) {
        rep.push(Diag::error(
            "aud.op-conservation",
            ctx,
            format!("layer_cost.{name} = {got} but the per-op costs sum to {want}"),
        ));
    }

    // (2) layer → phase linkage: layers × layer + (pp-1) × handoff
    let layers = rc.model.n_layers as u64;
    let pp = (rc.devices / rc.tp).max(1) as u64;
    let handoff = coll::cxl_p2p((batch * rc.model.d_model * 2) as u64, &rc.hw.cxl);
    let total = layer.repeat(layers).then(&handoff.repeat(pp.saturating_sub(1)));
    if rel(total.latency_ns, r.latency_ns) > REL_TOL {
        rep.push(Diag::error(
            "aud.op-conservation",
            ctx,
            format!(
                "re-composed phase latency {:.6} ns != reported {:.6} ns",
                total.latency_ns, r.latency_ns
            ),
        ));
    }
    let tokens_per_pass = match phase {
        Phase::Decode => batch as f64,
        Phase::Prefill => (batch * seq_len) as f64,
    };
    let stage_ns = total.latency_ns / pp as f64;
    let throughput = tokens_per_pass / (stage_ns / 1e9);
    if rel(throughput, r.throughput_tok_s) > REL_TOL {
        rep.push(Diag::error(
            "aud.op-conservation",
            ctx,
            format!(
                "re-derived throughput {throughput:.3} tok/s != reported {:.3}",
                r.throughput_tok_s
            ),
        ));
    }
    let layer_ns = layer.latency_ns.max(1e-9);
    for (name, got, want) in [
        ("nonlinear_frac", r.nonlinear_frac, nl_ns / layer_ns),
        ("collective_frac", r.collective_frac, coll_ns / layer_ns),
    ] {
        if rel(want, got) > REL_TOL {
            rep.push(Diag::error(
                "aud.op-conservation",
                ctx,
                format!("{name} = {got:.6} but the op classes sum to {want:.6}"),
            ));
        }
    }

    // (3) independent energy re-pricing of the re-composed counts
    let em = EnergyModel::new(&rc.hw.sram, rc.hw.hb.pj_per_bit);
    let mut want = em.dynamic(&total.counts).scale(1.0 / tokens_per_pass);
    want.static_pj =
        rc.devices as f64 * em.pim_device_static_w * (total.latency_ns / pp as f64)
            / tokens_per_pass;
    for ((name, got), (_, want_pj)) in r.energy.components().iter().zip(want.components().iter())
    {
        if (got - want_pj).abs() > REL_TOL * want_pj.abs().max(1.0) {
            rep.push(Diag::error(
                "aud.energy-conservation",
                ctx,
                format!(
                    "energy.{name} = {got:.6} pJ but re-pricing the op counts gives {want_pj:.6} pJ"
                ),
            ));
        }
    }
    rep.normalize();
    rep
}

fn rel(a: f64, b: f64) -> f64 {
    crate::util::stats::rel_err(a, b)
}

/// Bytes-in == bytes-out across every `arch/collective` closed form, and
/// degenerate shapes price to exactly zero events.
pub fn check_collective_identities(hw_label: &str, hw: &HwConfig) -> CheckReport {
    let mut rep = CheckReport::default();
    let cx = |what: &str| format!("{hw_label} {what}");
    for bytes in [1u64, 4096, 1 << 20] {
        let c = coll::cxl_p2p(bytes, &hw.cxl);
        let ctx = cx(&format!("cxl_p2p bytes={bytes}"));
        check_counter(&mut rep, &ctx, "cxl_bytes", c.counts.cxl_bytes, bytes);
        check_counter(&mut rep, &ctx, "total_events", c.counts.total_events(), bytes);
        for tp in [2u64, 3, 8] {
            let c = coll::cxl_allreduce(bytes, tp, &hw.cxl);
            let ctx = cx(&format!("cxl_allreduce bytes={bytes} tp={tp}"));
            let ring = 2 * bytes * (tp - 1) / tp;
            check_counter(&mut rep, &ctx, "cxl_bytes", c.counts.cxl_bytes, ring);
            check_counter(&mut rep, &ctx, "total_events", c.counts.total_events(), ring);
        }
        let back = bytes / 2;
        let c = coll::nlu_roundtrip(bytes, back, 33, 4, &hw.dram);
        let ctx = cx(&format!("nlu_roundtrip bytes={bytes}"));
        check_counter(&mut rep, &ctx, "gb_bytes", c.counts.gb_bytes, bytes + back);
        check_counter(&mut rep, &ctx, "nlu_ops", c.counts.nlu_ops, 33);
    }
    for (elems, banks) in [(4u64, 4u64), (64, 16), (33, 12)] {
        let edges = elems * (banks - 1);
        let r = coll::noc_reduce(elems, banks, &hw.noc);
        let ctx = cx(&format!("noc_reduce elems={elems} banks={banks}"));
        check_counter(&mut rep, &ctx, "noc_flit_hops", r.counts.noc_flit_hops, edges);
        check_counter(&mut rep, &ctx, "noc_alu_ops", r.counts.noc_alu_ops, edges);
        let b = coll::noc_broadcast(elems, banks, &hw.noc);
        let ctx = cx(&format!("noc_broadcast elems={elems} banks={banks}"));
        check_counter(&mut rep, &ctx, "noc_flit_hops", b.counts.noc_flit_hops, edges);
        check_counter(&mut rep, &ctx, "noc_alu_ops", b.counts.noc_alu_ops, 0);
    }
    for (e, rounds) in [(2u64, 8u64), (16, 4)] {
        let x = coll::noc_exp(e, rounds, &hw.noc);
        let ctx = cx(&format!("noc_exp elems={e} rounds={rounds}"));
        check_counter(&mut rep, &ctx, "noc_alu_ops", x.counts.noc_alu_ops, e * 4 * rounds);
        check_counter(&mut rep, &ctx, "noc_flit_hops", x.counts.noc_flit_hops, e * (2 * rounds + 2));
        let s = coll::noc_sqrt(e, rounds, &hw.noc);
        let ctx = cx(&format!("noc_sqrt elems={e} rounds={rounds}"));
        check_counter(&mut rep, &ctx, "noc_alu_ops", s.counts.noc_alu_ops, e * 3 * rounds);
        check_counter(&mut rep, &ctx, "noc_flit_hops", s.counts.noc_flit_hops, e * (2 * rounds + 3));
    }
    let st = coll::noc_scalar_stream(16, &hw.noc);
    let ctx = cx("noc_scalar_stream elems=16");
    check_counter(&mut rep, &ctx, "noc_alu_ops", st.counts.noc_alu_ops, 16);
    check_counter(&mut rep, &ctx, "noc_flit_hops", st.counts.noc_flit_hops, 32);
    for (what, c) in [
        ("noc_reduce elems=0", coll::noc_reduce(0, 8, &hw.noc)),
        ("noc_reduce banks=1", coll::noc_reduce(8, 1, &hw.noc)),
        ("noc_broadcast banks=1", coll::noc_broadcast(8, 1, &hw.noc)),
        ("noc_exp rounds=0", coll::noc_exp(8, 0, &hw.noc)),
        ("noc_sqrt elems=0", coll::noc_sqrt(0, 6, &hw.noc)),
        ("cxl_allreduce tp=1", coll::cxl_allreduce(4096, 1, &hw.cxl)),
        ("cxl_p2p bytes=0", coll::cxl_p2p(0, &hw.cxl)),
    ] {
        check_counter(&mut rep, &cx(what), "total_events", c.counts.total_events(), 0);
    }
    rep.normalize();
    rep
}

/// The cluster KV-migration path conserves bytes and bills them exactly
/// once at the CXL per-byte rate.
pub fn check_cluster_migration(ctx: &str, cr: &ClusterReport, rc: &RunConfig) -> CheckReport {
    let mut rep = CheckReport::default();
    let em = EnergyModel::new(&rc.hw.sram, rc.hw.hb.pj_per_bit);
    let want = cr.migration_bytes as f64 * em.cxl_pj_per_byte;
    if (cr.migration_energy_pj - want).abs() > REL_TOL * want.max(1.0) {
        rep.push(Diag::error(
            "aud.bytes-conservation",
            ctx,
            format!(
                "migration_energy_pj = {:.3} but {} bytes at {} pJ/B = {want:.3}",
                cr.migration_energy_pj, cr.migration_bytes, em.cxl_pj_per_byte
            ),
        ));
    }
    if (cr.migrations == 0) != (cr.migration_bytes == 0) {
        rep.push(Diag::error(
            "aud.bytes-conservation",
            ctx,
            format!(
                "{} migrations moved {} bytes (bytes and hand-offs must appear together)",
                cr.migrations, cr.migration_bytes
            ),
        ));
    }
    if cr.migration_energy_pj > cr.report.energy.cxl_pj * (1.0 + REL_TOL) {
        rep.push(Diag::error(
            "aud.bytes-conservation",
            ctx,
            format!(
                "migration energy {:.3} pJ exceeds the run's total CXL energy {:.3} pJ",
                cr.migration_energy_pj, cr.report.energy.cxl_pj
            ),
        ));
    }
    rep.normalize();
    rep
}

// ------------------------------------------------------- monotonicity

/// Latency and dynamic energy must be non-decreasing along pow2
/// batch/seq/KV chains at fixed everything-else. Runs against any
/// [`CostModel`]; the audit drives it with the static-mapping `System`
/// (the auto-mapper re-searches per shape class, so its minimum is only
/// guaranteed monotone where the search is exhaustive — never-lose is
/// its audited property instead).
pub fn check_monotonic(ctx: &str, m: &dyn CostModel, deep: bool) -> CheckReport {
    let mut rep = CheckReport::default();
    let rc = m.base();
    let em = EnergyModel::new(&rc.hw.sram, rc.hw.hb.pj_per_bit);
    let mut chain = |label: String, points: Vec<(String, OpCost)>| {
        for w in points.windows(2) {
            let (la, a) = &w[0];
            let (lb, b) = &w[1];
            let cctx = format!("{ctx} {label}");
            if b.latency_ns < a.latency_ns * (1.0 - REL_TOL) {
                rep.push(Diag::error(
                    "aud.monotonic",
                    cctx.clone(),
                    format!(
                        "latency decreased from {la} ({:.3} ns) to {lb} ({:.3} ns)",
                        a.latency_ns, b.latency_ns
                    ),
                ));
            }
            let (ea, eb) = (em.dynamic(&a.counts).total_pj(), em.dynamic(&b.counts).total_pj());
            if eb < ea * (1.0 - REL_TOL) {
                rep.push(Diag::error(
                    "aud.monotonic",
                    cctx,
                    format!("dynamic energy decreased from {la} ({ea:.3} pJ) to {lb} ({eb:.3} pJ)"),
                ));
            }
        }
    };
    for phase in [Phase::Prefill, Phase::Decode] {
        let seq = match phase {
            Phase::Prefill => 512,
            Phase::Decode => 1024,
        };
        let pts = lattice::batch_chain(deep)
            .into_iter()
            .map(|b| (format!("b={b}"), m.phase_report(phase, b, seq).layer_cost_total()))
            .collect();
        chain(format!("{} batch-chain s={seq}", phase.label()), pts);
        let pts = lattice::seq_chain(deep)
            .into_iter()
            .map(|s| (format!("s={s}"), m.phase_report(phase, 2, s).layer_cost_total()))
            .collect();
        chain(format!("{} seq-chain b=2", phase.label()), pts);
    }
    let pts = lattice::kv_chain(deep)
        .into_iter()
        .map(|kv| (format!("kv={kv}"), m.iteration_cost(0, 4, kv)))
        .collect();
    chain("decode kv-chain b=4".to_string(), pts);
    rep.normalize();
    rep
}

// ------------------------------------------------- cache / mapping coherence

/// Iteration-shape triples the coherence and never-lose checks probe
/// (prefill-only, decode-only, and a mixed chunked iteration).
const ITER_PROBES: [(usize, usize, usize); 3] = [(256, 0, 0), (0, 4, 1024), (128, 8, 2048)];

/// `candidate` must answer bit-identically to `reference` at every
/// anchor, and answer repeat queries with its own first answer (memo
/// stability). The audit drives this with `CachedCostModel` vs the bare
/// `System`, and with the auto-mapped model against itself.
pub fn check_model_coherence(
    ctx: &str,
    reference: &dyn CostModel,
    candidate: &dyn CostModel,
    anchors: &[ShapeAnchor],
) -> CheckReport {
    let mut rep = CheckReport::default();
    for a in anchors {
        let actx = format!("{ctx} {}", a.label());
        let want = reference.phase_report(a.phase, a.batch, a.seq_len);
        let got = candidate.phase_report(a.phase, a.batch, a.seq_len);
        let again = candidate.phase_report(a.phase, a.batch, a.seq_len);
        for (name, w, g, g2) in [
            ("latency_ns", want.latency_ns, got.latency_ns, again.latency_ns),
            ("throughput_tok_s", want.throughput_tok_s, got.throughput_tok_s, again.throughput_tok_s),
            ("energy.total_pj", want.energy.total_pj(), got.energy.total_pj(), again.energy.total_pj()),
        ] {
            if g.to_bits() != w.to_bits() {
                rep.push(Diag::error(
                    "aud.cache-coherence",
                    actx.clone(),
                    format!("{name} = {g:.6} diverges from the uncached reference {w:.6}"),
                ));
            }
            if g2.to_bits() != g.to_bits() {
                rep.push(Diag::error(
                    "aud.cache-coherence",
                    actx.clone(),
                    format!("{name} unstable across repeat queries: {g:.6} then {g2:.6}"),
                ));
            }
        }
        if let Some((name, g, w)) = first_count_diff(&got.layer_cost.counts, &want.layer_cost.counts)
        {
            rep.push(Diag::error(
                "aud.cache-coherence",
                actx.clone(),
                format!("layer_cost.{name} = {g} diverges from the uncached reference {w}"),
            ));
        }
        if got.ops.len() != want.ops.len() {
            rep.push(Diag::error(
                "aud.cache-coherence",
                actx,
                format!("{} ops reported vs {} uncached", got.ops.len(), want.ops.len()),
            ));
        }
    }
    for (p, d, kv) in ITER_PROBES {
        let actx = format!("{ctx} iter p={p} d={d} kv={kv}");
        let w = reference.iteration_cost(p, d, kv);
        let g = candidate.iteration_cost(p, d, kv);
        let g2 = candidate.iteration_cost(p, d, kv);
        if g.latency_ns.to_bits() != w.latency_ns.to_bits() || g.counts != w.counts {
            rep.push(Diag::error(
                "aud.cache-coherence",
                actx.clone(),
                format!(
                    "iteration_cost latency {:.6} ns diverges from the uncached {:.6} ns",
                    g.latency_ns, w.latency_ns
                ),
            ));
        }
        if g2.latency_ns.to_bits() != g.latency_ns.to_bits() || g2.counts != g.counts {
            rep.push(Diag::error("aud.cache-coherence", actx, "iteration_cost unstable across repeat queries".to_string()));
        }
    }
    rep.normalize();
    rep
}

/// Re-prove the auto-mapper's structural guarantee from the audit side:
/// at every anchor and iteration probe, the searched model never costs
/// more than the static mapping.
pub fn check_never_lose(
    ctx: &str,
    auto: &dyn CostModel,
    static_ref: &dyn CostModel,
    anchors: &[ShapeAnchor],
) -> CheckReport {
    let mut rep = CheckReport::default();
    for a in anchors {
        let s = static_ref.phase_report(a.phase, a.batch, a.seq_len).latency_ns;
        let g = auto.phase_report(a.phase, a.batch, a.seq_len).latency_ns;
        if g > s * (1.0 + REL_TOL) {
            rep.push(Diag::error(
                "aud.never-lose",
                format!("{ctx} {}", a.label()),
                format!("auto-mapped latency {g:.3} ns exceeds static {s:.3} ns"),
            ));
        }
    }
    for (p, d, kv) in ITER_PROBES {
        let s = static_ref.iteration_cost(p, d, kv).latency_ns;
        let g = auto.iteration_cost(p, d, kv).latency_ns;
        if g > s * (1.0 + REL_TOL) {
            rep.push(Diag::error(
                "aud.never-lose",
                format!("{ctx} iter p={p} d={d} kv={kv}"),
                format!("auto-mapped iteration {g:.3} ns exceeds static {s:.3} ns"),
            ));
        }
    }
    rep.normalize();
    rep
}

// ------------------------------------------------------- fidelity / fit

fn shape_parts(shape: &str) -> (u64, String) {
    let mut it = shape.split_whitespace();
    let vol = it
        .next()
        .and_then(|t| t.split('=').nth(1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    (vol, it.next().unwrap_or("").to_string())
}

/// Cross-fidelity coherence over the calibration anchor rows: finite
/// numbers, calibrated residual inside the gated band, raw ratio inside
/// its documented band (warning), and — per (collective, structural
/// param) group — the analytic and simulated tiers ranking anchor
/// volumes the same way (warning; both tiers are chunk-linear, so an
/// inversion means one of them lost linearity).
pub fn check_fidelity_anchors(anchors: &[CalibAnchor]) -> CheckReport {
    let mut rep = CheckReport::default();
    for a in anchors {
        let ctx = format!("{} {}", a.collective, a.shape);
        for (name, v) in [
            ("analytic_ns", a.analytic_ns),
            ("simulated_ns", a.simulated_ns),
            ("calibrated_ns", a.calibrated_ns),
        ] {
            num(&mut rep, &ctx, name, v);
        }
        if !(a.analytic_ns > 0.0 && a.simulated_ns > 0.0) {
            continue; // ratios are undefined at a degenerate anchor
        }
        let err = a.calibrated_err();
        if !err.is_finite() || err > FIDELITY_BAND {
            rep.push(Diag::error(
                "aud.fidelity-band",
                ctx.clone(),
                format!(
                    "calibrated residual {:.1}% exceeds the {:.0}% gate",
                    err * 100.0,
                    FIDELITY_BAND * 100.0
                ),
            ));
        }
        let ratio = a.raw_ratio();
        if ratio < RAW_RATIO_BAND.0 || ratio > RAW_RATIO_BAND.1 {
            rep.push(Diag::warning(
                "aud.fidelity-band",
                ctx,
                format!(
                    "raw sim/analytic ratio {ratio:.2} outside the documented {}-{}x band",
                    RAW_RATIO_BAND.0, RAW_RATIO_BAND.1
                ),
            ));
        }
    }
    let mut groups: BTreeMap<(String, String), Vec<(u64, f64, f64)>> = BTreeMap::new();
    for a in anchors {
        let (vol, param) = shape_parts(&a.shape);
        groups
            .entry((a.collective.to_string(), param))
            .or_default()
            .push((vol, a.analytic_ns, a.simulated_ns));
    }
    for ((collective, param), mut rows) in groups {
        rows.sort_by_key(|r| r.0);
        for w in rows.windows(2) {
            let (v0, a0, s0) = w[0];
            let (v1, a1, s1) = w[1];
            if (a1 >= a0) != (s1 >= s0) {
                rep.push(Diag::warning(
                    "aud.fidelity-band",
                    format!("{collective} {param}"),
                    format!(
                        "analytic and simulated tiers disagree on the ordering of volumes {v0} and {v1}"
                    ),
                ));
            }
        }
    }
    rep.normalize();
    rep
}

/// Every fitted NoC correction factor finite and inside the declared
/// bounds (rows from [`calibration_factors`]).
pub fn check_calibration_factors(rows: &[(&'static str, u64, f64)]) -> CheckReport {
    let mut rep = CheckReport::default();
    for (collective, key, factor) in rows {
        check_factor(&mut rep, collective, *key, *factor);
    }
    rep.normalize();
    rep
}

// ------------------------------------------------------------- drivers

/// Audit one lattice point: report sanity + conservation at every shape
/// anchor, cache coherence against the uncached reference, and — per
/// mapping mode — monotonicity chains (static) or the never-lose
/// re-proof (auto). The AttAcc roofline has its own simulator and no
/// PIM cost model, so it gets report sanity only.
pub fn audit_point(point: &AuditPoint, opts: &AuditOptions) -> CheckReport {
    let ctx = point.label();
    let mut rep = CheckReport::default();
    let rc = point.rc();
    let anchors = lattice::shape_anchors(opts.deep);
    if point.arch == ArchKind::AttAcc {
        for a in &anchors {
            let mut rc2 = rc.clone();
            rc2.phase = a.phase;
            rc2.batch = a.batch;
            rc2.seq_len = a.seq_len;
            let r = attacc::simulate(&rc2, &AttAccConfig::default());
            rep.extend(check_phase_sanity(&format!("{ctx} {}", a.label()), &r));
        }
        rep.normalize();
        return rep;
    }
    let sys = System::new(rc.clone());
    for a in &anchors {
        let actx = format!("{ctx} {}", a.label());
        let r = sys.run_shape(a.phase, a.batch, a.seq_len);
        rep.extend(check_phase_sanity(&actx, &r));
        rep.extend(check_phase_conservation(&actx, &r, &rc, a.phase, a.batch, a.seq_len));
    }
    let cached = CachedCostModel::new(System::new(rc.clone()));
    rep.extend(check_model_coherence(&format!("{ctx} cached"), &sys, &cached, &anchors));
    match point.mapping {
        MappingMode::Static => rep.extend(check_monotonic(&ctx, &sys, opts.deep)),
        MappingMode::Auto => {
            let auto = AutoMappedCostModel::new(rc.clone());
            for a in &anchors {
                let actx = format!("{ctx} {}", a.label());
                let r = auto.phase_report(a.phase, a.batch, a.seq_len);
                rep.extend(check_phase_sanity(&actx, &r));
                rep.extend(check_phase_conservation(&actx, &r, &rc, a.phase, a.batch, a.seq_len));
            }
            rep.extend(check_never_lose(&ctx, &auto, &sys, &anchors));
            // the searched model must also answer repeat queries stably
            rep.extend(check_model_coherence(&format!("{ctx} auto-repeat"), &auto, &auto, &anchors));
        }
    }
    rep.normalize();
    rep
}

/// The arch-independent audit slice, run once per `compair audit`
/// invocation: collective closed-form identities on both shipped
/// hardware points, the calibration anchors and fitted factors, and one
/// serving + one disaggregated-cluster sample routed through the shared
/// report validator and the KV-migration conservation check.
pub fn check_global(opts: &AuditOptions) -> CheckReport {
    let mut rep = CheckReport::default();
    rep.extend(check_collective_identities("paper", &HwConfig::paper()));
    rep.extend(check_collective_identities("paper-opt", &HwConfig::paper_opt()));
    rep.extend(check_fidelity_anchors(&calibration_report(&HwConfig::paper(), 1)));
    rep.extend(check_calibration_factors(&calibration_factors(&HwConfig::paper(), 1)));
    if opts.deep {
        rep.extend(check_fidelity_anchors(&calibration_report(&HwConfig::paper_opt(), 1)));
        rep.extend(check_calibration_factors(&calibration_factors(&HwConfig::paper_opt(), 1)));
    }
    let rc = RunConfig::new(ArchKind::CompAirOpt, crate::config::ModelConfig::tiny());
    let cfg = ServeConfig { n_requests: 16, prompt_len: 64, gen_len: 4, ..Default::default() };
    let sr = Server::new(rc.clone(), cfg.clone()).run();
    rep.extend(check_serve_report("serve compair-opt/tiny", &sr));
    let ccfg =
        ClusterConfig { replicas: 2, disagg: Some((1, 1)), router: RouterPolicy::RoundRobin };
    let cr = Cluster::new(rc.clone(), cfg, ccfg).run();
    rep.extend(check_serve_report("cluster compair-opt/tiny", &cr.report));
    rep.extend(check_cluster_migration("cluster compair-opt/tiny", &cr, &rc));
    rep.normalize();
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn shipped_point_audits_clean() {
        let p = AuditPoint {
            arch: ArchKind::CompAirOpt,
            model: ModelConfig::tiny(),
            fidelity: crate::config::NocFidelity::Analytic,
            mapping: MappingMode::Static,
        };
        let rep = audit_point(&p, &AuditOptions::default());
        assert!(rep.is_clean(), "{}", rep.render_brief());
    }

    #[test]
    fn collective_identities_hold_on_shipped_hardware() {
        for (label, hw) in [("paper", HwConfig::paper()), ("paper-opt", HwConfig::paper_opt())] {
            let rep = check_collective_identities(label, &hw);
            assert!(rep.diags.is_empty(), "{label}:\n{}", rep.render_brief());
        }
    }

    #[test]
    fn counter_mismatch_fires_bytes_conservation() {
        let mut rep = CheckReport::default();
        check_counter(&mut rep, "fabricated", "cxl_bytes", 5, 6);
        assert!(rep.has_code("aud.bytes-conservation"));
    }

    #[test]
    fn factor_bounds_accept_unity_reject_runaway() {
        let mut rep = CheckReport::default();
        check_factor(&mut rep, "reduce", 16, 1.0);
        assert!(rep.diags.is_empty());
        check_factor(&mut rep, "reduce", 16, FACTOR_BOUNDS.1 * 2.0);
        assert!(rep.has_code("aud.calibration-bounds"));
    }
}
