//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load the AOT-compiled JAX/Pallas artifacts (L1/L2) through the PJRT
//!    runtime and run real numerics (a Curry-softmax row + one decode step
//!    of the tiny transformer).
//! 2. Simulate the same decode step on the CompAir hardware model (L3) and
//!    print latency/energy vs the CENT baseline.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use compair::config::{ArchKind, ModelConfig, RunConfig};
use compair::runtime::{Runtime, Tensor};
use compair::Engine;
use compair::util::table::{fenergy_pj, fnum, ftime_ns};
use compair::util::XorShiftRng;

fn main() -> compair::runtime::Result<()> {
    // ---- numerics through the AOT artifacts ----
    let mut rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    let softmax = rt.load("curry_softmax")?;
    let mut rng = XorShiftRng::new(1);
    let scores = rng.vec_f32(8 * 128, -4.0, 4.0);
    let probs = softmax.run(&[Tensor::new(scores, &[8, 128])])?;
    let row0: f32 = probs[0].data[..128].iter().sum();
    println!("curry_softmax row 0 sums to {row0:.4} (Pallas kernel via PJRT)");

    let decode = rt.load("decode_step")?;
    let (l, b, h, s, dh, d) = (2usize, 2usize, 4usize, 64usize, 16usize, 64usize);
    let x = rng.vec_f32(b * d, -0.5, 0.5);
    let zeros = vec![0.0f32; l * b * h * s * dh];
    let out = decode.run_with_i32_scalar(
        &[
            Tensor::new(x, &[b, 1, d]),
            Tensor::new(zeros.clone(), &[l, b, h, s, dh]),
            Tensor::new(zeros, &[l, b, h, s, dh]),
        ],
        0,
    )?;
    println!(
        "decode_step: hidden out {:?}, KV caches updated ({} values written)",
        out[0].dims,
        out[1].data.iter().filter(|v| **v != 0.0).count()
    );

    // ---- timing/energy through the hardware simulator ----
    println!("\nsimulated hardware (Llama2-7B, batch=16, 4K context, TP=8):");
    for arch_kind in [ArchKind::Cent, ArchKind::CompAirOpt] {
        let mut rc = RunConfig::new(arch_kind, ModelConfig::llama2_7b());
        rc.batch = 16;
        rc.seq_len = 4096;
        let r = Engine::new(rc).simulate();
        println!(
            "  {:<14} latency/token {}  throughput {} tok/s  energy/token {}",
            arch_kind.label(),
            ftime_ns(r.latency_ns),
            fnum(r.throughput_tok_s),
            fenergy_pj(r.energy.total_pj()),
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
