//! End-to-end serving driver (the EXPERIMENTS.md §E2E run): a continuous-
//! batching server where every decode iteration runs BOTH
//!
//! * real numerics — the AOT-compiled tiny-Llama `decode_step` executed on
//!   the PJRT CPU client (Python is never invoked), and
//! * hardware timing/energy — the CompAir simulator costing the same
//!   iteration shape,
//!
//! proving the three layers compose: L1 Pallas kernels inside the L2 JAX
//! block, loaded and driven by the L3 rust coordinator.
//!
//! Run: `make artifacts && cargo run --release --example serve_decode`

use compair::arch::{CachedCostModel, CostModel, System};
use compair::config::{ArchKind, ModelConfig, Phase, RunConfig};
use compair::coordinator::{Batcher, BatcherConfig, Request};
use compair::runtime::{Runtime, Tensor};
use compair::util::stats::percentile;
use compair::util::table::{fenergy_pj, fnum, ftime_ns, Table};
use compair::util::XorShiftRng;

const L: usize = 2;
const B: usize = 2; // artifact batch (fixed at AOT time)
const H: usize = 4;
const S: usize = 64; // max_seq
const DH: usize = 16;
const D: usize = 64;

fn main() -> compair::runtime::Result<()> {
    let mut rt = Runtime::cpu()?;
    let decode = rt.load("decode_step")?;

    // Workload: 12 requests, short prompts, 8 generated tokens each.
    let mut rng = XorShiftRng::new(7);
    let n_requests = 12usize;
    let gen_len = 8usize;
    let prompt_len = 4usize;

    let mut batcher = Batcher::new(BatcherConfig {
        max_batch: B,
        max_kv_tokens: 4096,
        queue_cap: 64,
        ..Default::default()
    });
    // pre-draw arrivals; requests are offered to the batcher only once the
    // simulated clock passes their arrival time
    let mut pending: Vec<Request> = Vec::new();
    let mut arrival = 0u64;
    for id in 0..n_requests {
        arrival += (rng.next_exp(2000.0) * 1e9) as u64;
        pending.push(Request::new(id as u64, prompt_len, gen_len, arrival));
    }

    // Simulator for per-iteration timing (tiny model on CompAir): a cached
    // cost model, so repeated iteration shapes memoize instead of
    // re-lowering the op-graph every decode step.
    let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::tiny());
    rc.tp = 1;
    rc.devices = 1;
    rc.phase = Phase::Decode;
    let cm = CachedCostModel::new(System::new(rc));

    // Per-slot state: hidden vector + position; KV caches live as one
    // [L,B,H,S,DH] tensor pair the artifact threads through.
    let mut k_cache = vec![0.0f32; L * B * H * S * DH];
    let mut v_cache = vec![0.0f32; L * B * H * S * DH];
    let mut hidden: Vec<Vec<f32>> = vec![rng.vec_f32(D, -0.5, 0.5); B];
    let mut pos = 0usize;

    let mut now = 0u64;
    let mut iterations = 0u64;
    let mut tokens = 0u64;
    let mut sim_ns_total = 0.0f64;
    let mut energy_pj_total = 0.0f64;
    let wall = std::time::Instant::now();
    let mut iter_wall_ns: Vec<f64> = Vec::new();

    while (!pending.is_empty() || !batcher.idle()) && pos + 1 < S {
        // deliver arrivals due by `now`; if everything is quiet, jump the
        // clock to the next arrival
        if batcher.idle() {
            if let Some(next) = pending.first().map(|r| r.arrived_ns) {
                now = now.max(next);
            }
        }
        while pending.first().map(|r| r.arrived_ns <= now).unwrap_or(false) {
            let r = pending.remove(0);
            batcher.offer(r);
        }
        batcher.admit(now);
        let pre = batcher.prefill_set();
        batcher.finish_prefill(&pre, now);
        let active = batcher.active.iter().filter(|s| s.is_prefilled() && !s.done()).count();
        if active == 0 {
            now += 1000;
            continue;
        }

        // --- real numerics: one decode_step on the PJRT client ---
        let x: Vec<f32> = (0..B).flat_map(|i| hidden[i % hidden.len()].clone()).collect();
        let t0 = std::time::Instant::now();
        let out = decode.run_with_i32_scalar(
            &[
                Tensor::new(x, &[B, 1, D]),
                Tensor::new(k_cache.clone(), &[L, B, H, S, DH]),
                Tensor::new(v_cache.clone(), &[L, B, H, S, DH]),
            ],
            pos as i32,
        )?;
        iter_wall_ns.push(t0.elapsed().as_nanos() as f64);
        for i in 0..B {
            hidden[i] = out[0].data[i * D..(i + 1) * D].to_vec();
            assert!(hidden[i].iter().all(|v| v.is_finite()), "numerics diverged");
        }
        k_cache = out[1].data.clone();
        v_cache = out[2].data.clone();
        pos += 1;

        // --- simulated hardware cost of the same iteration shape ---
        let rep = cm.phase_report(Phase::Decode, active, pos.max(1));
        sim_ns_total += rep.latency_ns;
        energy_pj_total += rep.energy.total_pj() * active as f64;

        now += rep.latency_ns as u64;
        let (n, _) = batcher.decode_step(now);
        tokens += n as u64;
        iterations += 1;
    }
    let wall_elapsed = wall.elapsed();

    // ---- report ----
    let mut t = Table::new("serve_decode — end-to-end run", &["metric", "value"]);
    t.rowv(vec!["requests completed".into(), batcher.completed.len().to_string()]);
    t.rowv(vec!["decode iterations".into(), iterations.to_string()]);
    t.rowv(vec!["tokens generated".into(), tokens.to_string()]);
    t.rowv(vec![
        "simulated time".into(),
        ftime_ns(sim_ns_total),
    ]);
    t.rowv(vec![
        "simulated throughput".into(),
        format!("{} tok/s", fnum(tokens as f64 / (sim_ns_total / 1e9))),
    ]);
    t.rowv(vec![
        "simulated energy".into(),
        fenergy_pj(energy_pj_total),
    ]);
    t.rowv(vec![
        "PJRT wallclock/iter p50".into(),
        ftime_ns(percentile(&iter_wall_ns, 50.0)),
    ]);
    t.rowv(vec![
        "PJRT wallclock/iter p99".into(),
        ftime_ns(percentile(&iter_wall_ns, 99.0)),
    ]);
    t.rowv(vec!["total wallclock".into(), format!("{:?}", wall_elapsed)]);
    t.print();

    let lats: Vec<f64> = batcher
        .completed
        .iter()
        .map(|(s, t)| (*t - s.req.arrived_ns) as f64)
        .collect();
    if !lats.is_empty() {
        println!(
            "request latency (simulated) p50 {} / p99 {}",
            ftime_ns(percentile(&lats, 50.0)),
            ftime_ns(percentile(&lats, 99.0)),
        );
    }
    assert!(tokens > 0, "no tokens generated");
    println!("serve_decode OK — all layers composed");
    Ok(())
}
