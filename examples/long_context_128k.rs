//! Long-context study (the Fig 19 scenario as a standalone app): decode at
//! 128K context on Qwen-72B and GPT3-175B, comparing CENT and CompAir with
//! full per-op and per-component energy breakdowns.
//!
//! Run: `cargo run --release --example long_context_128k`

use compair::config::{ArchKind, ModelConfig, RunConfig};
use compair::Engine;
use compair::util::table::{fenergy_pj, fnum, ftime_ns, Table};
use compair::workload::OpClass;

fn main() {
    for model in [ModelConfig::qwen_72b(), ModelConfig::gpt3_175b()] {
        println!("==== {} @ 128K context, batch 16, TP=8, 32 devices ====", model.name);
        let mut per_arch = Vec::new();
        for arch in [ArchKind::Cent, ArchKind::CentCurry, ArchKind::CompAirOpt] {
            let mut rc = RunConfig::new(arch, model.clone());
            rc.batch = 16;
            rc.seq_len = 128 * 1024;
            rc.gen_len = 8192;
            let r = Engine::new(rc).simulate();
            per_arch.push((arch, r));
        }
        let mut t = Table::new(
            "summary",
            &["arch", "lat/token", "tok/s", "nonlinear", "energy/token"],
        );
        let base = per_arch[0].1.latency_ns;
        for (arch, r) in &per_arch {
            t.rowv(vec![
                arch.label().into(),
                format!("{} ({})", ftime_ns(r.latency_ns), format!("{:.2}x", base / r.latency_ns)),
                fnum(r.throughput_tok_s),
                format!("{:.1}%", r.nonlinear_frac * 100.0),
                fenergy_pj(r.energy.total_pj()),
            ]);
        }
        t.print();

        // per-op time breakdown for the winner
        let (_, best) = per_arch.last().unwrap();
        let mut t2 = Table::new("CompAir_Opt per-op breakdown (one layer)", &["op", "time", "share"]);
        let total = best.layer_cost.latency_ns;
        for op in &best.ops {
            t2.rowv(vec![
                op.name.clone(),
                ftime_ns(op.cost.latency_ns),
                format!("{:.1}%", op.cost.latency_ns / total * 100.0),
            ]);
        }
        t2.print();

        // energy by component
        let e = &best.energy;
        let mut t3 = Table::new("CompAir_Opt energy/token by component", &["component", "energy"]);
        for (name, v) in [
            ("dram", e.dram_pj),
            ("sram", e.sram_pj),
            ("hybrid bonding", e.hb_pj),
            ("noc", e.noc_pj),
            ("global buffer", e.gb_pj),
            ("cxl", e.cxl_pj),
            ("static", e.static_pj),
        ] {
            t3.rowv(vec![name.into(), fenergy_pj(v)]);
        }
        t3.print();

        // sanity: the nonlinear share must be material at 128K on CENT
        let cent_nl = per_arch[0].1.nonlinear_frac;
        let nl_ops: f64 = per_arch[0]
            .1
            .ops
            .iter()
            .filter(|o| o.class == OpClass::NonLinear)
            .map(|o| o.cost.latency_ns)
            .sum();
        println!(
            "CENT spends {:.1}% of layer time ({}/layer) in non-linear ops at 128K\n",
            cent_nl * 100.0,
            ftime_ns(nl_ops)
        );
    }
}
