//! Profiling workload: hammers the hierarchical-ISA machine's exp program
//! in a tight loop so `perf`/flamegraph sessions have a steady hot path to
//! sample (the §Perf optimization loop's target binary).
//!
//! Run: `cargo run --release --example profexp`

use compair::config::{HwConfig, SramGang};
use compair::isa::{Machine, RowProgram};

fn main() {
    let hw = HwConfig::paper();
    for _ in 0..500 {
        let mut m = Machine::new(&hw, SramGang::In256Out16);
        let xs: Vec<f32> = (0..16).map(|i| 0.05 * i as f32 - 0.4).collect();
        m.write_row(0, 0, &xs);
        let p = RowProgram::exp_program(0, 2000, 16, 6, 1);
        compair::util::bench::sink(m.run(&p, true));
    }
}
