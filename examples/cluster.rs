//! Cluster sweep: the serving scenarios driven across multi-replica
//! deployments on the modeled CXL fabric — replica-count scaling, router
//! policy face-off, and colocated vs disaggregated prefill/decode with
//! priced KV migration.
//!
//! Run: `cargo run --release --example cluster`

use compair::config::{ArchKind, ModelConfig, RunConfig};
use compair::coordinator::{cluster::render_cluster_summary, ClusterConfig, RouterPolicy};
use compair::util::table::{fbytes, fenergy_pj, fnum, ftime_ns, Table};
use compair::workload::Scenario;
use compair::Engine;

fn engine() -> Engine {
    let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
    rc.tp = 8;
    rc.devices = 32;
    Engine::new(rc)
}

fn main() {
    // ---- replica scaling on the mixed multi-tenant blend ----
    println!("==== replica scaling: mixed blend, CompAir_Opt, llama2-7b ====");
    let mut t = Table::new(
        "colocated, least-kv router, 32 requests, seed 42",
        &["replicas", "makespan", "tok/s", "ttft p99", "slo%", "energy/tok"],
    );
    for replicas in [1usize, 2, 4, 8] {
        let cfg = ClusterConfig { replicas, disagg: None, router: RouterPolicy::LeastLoadedKv };
        let r = engine().cluster_scenario(Scenario::by_name("mixed").unwrap(), 32, 42, cfg)
            .cluster;
        t.rowv(vec![
            replicas.to_string(),
            ftime_ns(r.report.makespan_ns as f64),
            fnum(r.report.throughput_tok_s),
            ftime_ns(r.report.ttft_p99_ns),
            format!("{:.1}%", r.report.slo_attainment * 100.0),
            fenergy_pj(r.report.energy_per_token_pj),
        ]);
    }
    t.print();

    // ---- router policy face-off under bursty traffic ----
    println!("\n==== router policies: bursty diurnal traffic, 4 replicas ====");
    let mut t = Table::new(
        "colocated, 48 requests, seed 42",
        &["router", "ttft p50", "ttft p99", "slo%", "rejected"],
    );
    for router in [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastLoadedKv,
        RouterPolicy::DeadlineAware,
    ] {
        let cfg = ClusterConfig { replicas: 4, disagg: None, router };
        let r = engine().cluster_scenario(Scenario::by_name("bursty").unwrap(), 48, 42, cfg)
            .cluster;
        t.rowv(vec![
            router.label().to_string(),
            ftime_ns(r.report.ttft_p50_ns),
            ftime_ns(r.report.ttft_p99_ns),
            format!("{:.1}%", r.report.slo_attainment * 100.0),
            r.report.rejected.to_string(),
        ]);
    }
    t.print();

    // ---- colocated vs disaggregated, with the migration bill ----
    println!("\n==== colocated vs disaggregated (4 replicas) per scenario ====");
    let mut t = Table::new(
        "least-kv router, seed 42",
        &["scenario", "mode", "tok/s", "ttft p99", "slo%", "energy/tok", "kv migrated"],
    );
    for sc in Scenario::all() {
        let n = sc.default_requests.min(16);
        for disagg in [None, Some((2usize, 2usize))] {
            let cfg = ClusterConfig {
                replicas: 4,
                disagg,
                router: RouterPolicy::LeastLoadedKv,
            };
            let r = engine().cluster_scenario(sc.clone(), n, 42, cfg).cluster;
            t.rowv(vec![
                sc.name.to_string(),
                r.mode(),
                fnum(r.report.throughput_tok_s),
                ftime_ns(r.report.ttft_p99_ns),
                format!("{:.1}%", r.report.slo_attainment * 100.0),
                fenergy_pj(r.report.energy_per_token_pj),
                fbytes(r.migration_bytes),
            ]);
        }
    }
    t.print();

    // ---- one full disaggregated run, with per-replica detail ----
    println!("\n==== disaggregated chat serving, 2 prefill : 2 decode ====");
    let cfg = ClusterConfig {
        replicas: 4,
        disagg: Some((2, 2)),
        router: RouterPolicy::DeadlineAware,
    };
    let r = engine().cluster_scenario(Scenario::by_name("chat").unwrap(), 32, 42, cfg).cluster;
    print!("{}", render_cluster_summary(&r));
    r.replica_table().print();
    r.report.class_table("per-class SLO report").print();
}
