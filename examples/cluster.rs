//! Cluster sweep: the serving scenarios driven across multi-replica
//! deployments on the modeled CXL fabric — replica-count scaling, router
//! policy face-off, and colocated vs disaggregated prefill/decode with
//! priced KV migration.
//!
//! Run: `cargo run --release --example cluster [-- --jobs N|auto]`
//!
//! Every sweep cell (replica count, router policy, scenario × mode) is its
//! own pool job; the submission-order merge keeps the tables byte-identical
//! to --jobs 1.

use compair::config::{ArchKind, ModelConfig, RunConfig};
use compair::coordinator::{cluster::render_cluster_summary, ClusterConfig, RouterPolicy};
use compair::util::pool::{default_jobs, par_map_indexed};
use compair::util::table::{fbytes, fenergy_pj, fnum, ftime_ns, Table};
use compair::workload::Scenario;
use compair::Engine;

/// Minimal `--jobs N|auto` parser (examples don't pull in the CLI layer).
fn jobs_from_args() -> usize {
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let v = match a.strip_prefix("--jobs=") {
            Some(v) => Some(v.to_string()),
            None if a == "--jobs" => it.next(),
            None => continue,
        };
        match v.as_deref() {
            Some("auto") => return default_jobs(),
            Some(s) => match s.parse::<usize>() {
                Ok(n) if n >= 1 => return n,
                _ => {
                    eprintln!("--jobs expects a positive integer or 'auto', got '{s}'");
                    std::process::exit(2);
                }
            },
            None => {
                eprintln!("--jobs expects a value");
                std::process::exit(2);
            }
        }
    }
    default_jobs()
}

fn engine() -> Engine {
    let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
    rc.tp = 8;
    rc.devices = 32;
    Engine::new(rc)
}

fn main() {
    let jobs = jobs_from_args();

    // ---- replica scaling on the mixed multi-tenant blend ----
    // each replica count is a pool job with its own Engine (per-worker
    // memoization); rows land in sweep order
    println!("==== replica scaling: mixed blend, CompAir_Opt, llama2-7b ====");
    let mut t = Table::new(
        "colocated, least-kv router, 32 requests, seed 42",
        &["replicas", "makespan", "tok/s", "ttft p99", "slo%", "energy/tok"],
    );
    let rows = par_map_indexed(jobs, vec![1usize, 2, 4, 8], |_, replicas| {
        let cfg = ClusterConfig { replicas, disagg: None, router: RouterPolicy::LeastLoadedKv };
        let r = engine().cluster_scenario(Scenario::by_name("mixed").unwrap(), 32, 42, cfg)
            .cluster;
        vec![
            replicas.to_string(),
            ftime_ns(r.report.makespan_ns as f64),
            fnum(r.report.throughput_tok_s),
            ftime_ns(r.report.ttft_p99_ns),
            format!("{:.1}%", r.report.slo_attainment * 100.0),
            fenergy_pj(r.report.energy_per_token_pj),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.print();

    // ---- router policy face-off under bursty traffic ----
    println!("\n==== router policies: bursty diurnal traffic, 4 replicas ====");
    let mut t = Table::new(
        "colocated, 48 requests, seed 42",
        &["router", "ttft p50", "ttft p99", "slo%", "rejected"],
    );
    let routers = vec![
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastLoadedKv,
        RouterPolicy::DeadlineAware,
    ];
    let rows = par_map_indexed(jobs, routers, |_, router| {
        let cfg = ClusterConfig { replicas: 4, disagg: None, router };
        let r = engine().cluster_scenario(Scenario::by_name("bursty").unwrap(), 48, 42, cfg)
            .cluster;
        vec![
            router.label().to_string(),
            ftime_ns(r.report.ttft_p50_ns),
            ftime_ns(r.report.ttft_p99_ns),
            format!("{:.1}%", r.report.slo_attainment * 100.0),
            r.report.rejected.to_string(),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.print();

    // ---- colocated vs disaggregated, with the migration bill ----
    println!("\n==== colocated vs disaggregated (4 replicas) per scenario ====");
    let mut t = Table::new(
        "least-kv router, seed 42",
        &["scenario", "mode", "tok/s", "ttft p99", "slo%", "energy/tok", "kv migrated"],
    );
    let mut cells = Vec::new();
    for sc in Scenario::all() {
        for disagg in [None, Some((2usize, 2usize))] {
            cells.push((sc.clone(), disagg));
        }
    }
    let rows = par_map_indexed(jobs, cells, |_, (sc, disagg)| {
        let n = sc.default_requests.min(16);
        let cfg = ClusterConfig { replicas: 4, disagg, router: RouterPolicy::LeastLoadedKv };
        let r = engine().cluster_scenario(sc.clone(), n, 42, cfg).cluster;
        vec![
            sc.name.to_string(),
            r.mode(),
            fnum(r.report.throughput_tok_s),
            ftime_ns(r.report.ttft_p99_ns),
            format!("{:.1}%", r.report.slo_attainment * 100.0),
            fenergy_pj(r.report.energy_per_token_pj),
            fbytes(r.migration_bytes),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.print();

    // ---- one full disaggregated run, with per-replica detail ----
    println!("\n==== disaggregated chat serving, 2 prefill : 2 decode ====");
    let cfg = ClusterConfig {
        replicas: 4,
        disagg: Some((2, 2)),
        router: RouterPolicy::DeadlineAware,
    };
    let r = engine().cluster_scenario(Scenario::by_name("chat").unwrap(), 32, 42, cfg).cluster;
    print!("{}", render_cluster_summary(&r));
    r.replica_table().print();
    r.report.class_table("per-class SLO report").print();
}
