//! Hierarchical-ISA playground: programs the CompAir channel machine with
//! Row-Level instructions (Table 1), shows the autonomous translation
//! (reduce-tree instantiation + path-generation fusion), and validates the
//! functional results against closed-form math.
//!
//! Run: `cargo run --release --example isa_playground`

use compair::config::{HwConfig, SramGang};
use compair::isa::{plan, Machine, Plan, RowInst, RowProgram, ALL_BANKS};
use compair::noc::{exchange, StepOp};
use compair::util::table::{ftime_ns, Table};

fn main() {
    let hw = HwConfig::paper();
    let mut m = Machine::new(&hw, SramGang::In256Out16);

    // 1. RoPE rearrangement: NoC_Exchange(R-, src, dst, 1, 2) on all banks.
    println!("-- RoPE rearrangement (NoC_Exchange R-) --");
    let head: Vec<f32> = (1..=16).map(|i| i as f32 * 0.25).collect();
    for b in 0..16 {
        m.write_row(b, 0, &head);
    }
    let mut p = RowProgram::new();
    p.push(RowInst::rope_exchange(0, 100, head.len()));
    let c = m.run(&p, true);
    assert_eq!(m.read_row(3, 100, head.len()), exchange::rope_rearrange(&head));
    println!("   16 banks rearranged a {}-elem head each in {}", head.len(), ftime_ns(c.latency_ns));

    // 2. Softmax denominator: per-bank exp + NoC_Reduce to bank 0.
    println!("-- distributed exp + tree reduce (softmax denominator) --");
    for b in 0..16 {
        m.write_row(b, 200, &[-(b as f32) / 8.0]);
    }
    let mut p = RowProgram::new();
    // exp of each bank's score (1 elem per bank), then sum across banks
    for inst in RowProgram::exp_program(200, 300, 1, 6, ALL_BANKS).insts {
        p.push(inst);
    }
    p.push(RowInst::NocReduce {
        op: StepOp::Add,
        src: 300,
        dst: 400,
        mask: ALL_BANKS,
        dst_bank: 0,
        len: 1,
    });
    let c = m.run(&p, true);
    let got = m.read_row(0, 400, 1)[0];
    let want: f32 = (0..16).map(|b| compair::noc::curry_exp(-(b as f32) / 8.0, 6)).sum();
    println!("   Σ exp(score_b) = {got:.4} (expected {want:.4}), in {}", ftime_ns(c.latency_ns));
    assert!((got - want).abs() < 0.05);

    // 3. SRAM-PIM FC tile: SRAM_Write + SRAM_Compute on bank 0.
    println!("-- SRAM-PIM FC tile (SRAM_Write / SRAM_Compute) --");
    let w: Vec<f32> = (0..64).map(|i| ((i % 7) as f32 - 3.0) * 0.1).collect(); // 8x8
    let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.2).collect();
    m.write_row(0, 500, &w);
    m.write_row(0, 600, &x);
    let mut p = RowProgram::new();
    p.push(RowInst::SramWrite { addr: 500, mask: 1, len: 64 });
    p.push(RowInst::SramCompute { src: 600, dst: 700, mask: 1, len: 8 });
    let c = m.run(&p, true);
    let y = m.read_row(0, 700, 8);
    println!("   y = {:?} in {}", &y[..4], ftime_ns(c.latency_ns));

    // 4. Show the translation plan for the exponential program.
    println!("-- autonomous translation (Fig 14B) --");
    let prog = RowProgram::exp_program(0, 100, 4, 6, ALL_BANKS);
    let mut t = Table::new("plan(fuse=true)", &["unit", "detail"]);
    for pl in plan(&prog.insts, true) {
        match pl {
            Plan::Chain(ch) => {
                t.rowv(vec![
                    "fused chain".into(),
                    format!(
                        "{} row insts -> {} path steps x IterNum {} (lane width {})",
                        ch.absorbed,
                        ch.steps.len(),
                        ch.iter_num,
                        ch.lane_width()
                    ),
                ]);
            }
            Plan::Other(i) => {
                t.rowv(vec!["passthrough".into(), format!("{i:?}")]);
            }
        }
    }
    t.print();
    println!("isa_playground OK");
}
