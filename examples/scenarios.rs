//! Scenario sweep: every named serving scenario driven end to end through
//! the SLO-aware continuous batcher on the CompAir hardware model, with
//! per-class SLO breakdowns, followed by the CENT-vs-CompAir face-off on
//! the mixed multi-tenant blend.
//!
//! Run: `cargo run --release --example scenarios [-- --jobs N|auto]`
//!
//! Each scenario (and each face-off arch) is its own pool job; the
//! submission-order merge keeps the printout byte-identical to --jobs 1.

use compair::config::{ArchKind, ModelConfig, RunConfig};
use compair::coordinator::serving;
use compair::util::pool::{default_jobs, par_map_indexed};
use compair::util::table::{fenergy_pj, fnum, ftime_ns, Table};
use compair::workload::Scenario;
use compair::Engine;

/// Minimal `--jobs N|auto` parser (examples don't pull in the CLI layer).
fn jobs_from_args() -> usize {
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let v = match a.strip_prefix("--jobs=") {
            Some(v) => Some(v.to_string()),
            None if a == "--jobs" => it.next(),
            None => continue,
        };
        match v.as_deref() {
            Some("auto") => return default_jobs(),
            Some(s) => match s.parse::<usize>() {
                Ok(n) if n >= 1 => return n,
                _ => {
                    eprintln!("--jobs expects a positive integer or 'auto', got '{s}'");
                    std::process::exit(2);
                }
            },
            None => {
                eprintln!("--jobs expects a value");
                std::process::exit(2);
            }
        }
    }
    default_jobs()
}

fn engine(arch: ArchKind) -> Engine {
    let mut rc = RunConfig::new(arch, ModelConfig::llama2_7b());
    rc.tp = 8;
    rc.devices = 32;
    Engine::new(rc)
}

fn main() {
    let jobs = jobs_from_args();

    println!("==== scenario sweep: CompAir_Opt, llama2-7b, TP=8, 32 devices ====\n");
    // one pool job per scenario: each worker builds its own Engine (the
    // memoizing cost model is per-instance), renders its block off-thread,
    // and the merge prints them in Scenario::all() order
    let blocks = par_map_indexed(jobs, Scenario::all(), |_, sc| {
        let name = sc.name;
        let desc = sc.description;
        let n = sc.default_requests;
        let sr = engine(ArchKind::CompAirOpt).serve_scenario(sc, n, 42);
        let mut out = format!("-- {name}: {desc} --\n");
        out.push_str(&serving::render_summary(&sr.report));
        out.push_str(&sr.report.class_table("per-class").render());
        out
    });
    for b in blocks {
        println!("{b}");
    }

    println!("==== mixed multi-tenant blend across architectures ====");
    let mut t = Table::new(
        "same trace, same SLOs",
        &["arch", "makespan", "tok/s", "ttft p99", "slo%", "energy/tok"],
    );
    let archs = vec![ArchKind::Cent, ArchKind::CentCurry, ArchKind::CompAirOpt];
    let rows = par_map_indexed(jobs, archs, |_, arch| {
        let sc = Scenario::by_name("mixed").unwrap();
        let r = engine(arch).serve_scenario(sc, 48, 42).report;
        vec![
            arch.label().to_string(),
            ftime_ns(r.makespan_ns as f64),
            fnum(r.throughput_tok_s),
            ftime_ns(r.ttft_p99_ns),
            format!("{:.1}%", r.slo_attainment * 100.0),
            fenergy_pj(r.energy_per_token_pj),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.print();
}
