//! Scenario sweep: every named serving scenario driven end to end through
//! the SLO-aware continuous batcher on the CompAir hardware model, with
//! per-class SLO breakdowns, followed by the CENT-vs-CompAir face-off on
//! the mixed multi-tenant blend.
//!
//! Run: `cargo run --release --example scenarios`

use compair::config::{ArchKind, ModelConfig, RunConfig};
use compair::coordinator::serving;
use compair::util::table::{fenergy_pj, fnum, ftime_ns, Table};
use compair::workload::Scenario;
use compair::Engine;

fn engine(arch: ArchKind) -> Engine {
    let mut rc = RunConfig::new(arch, ModelConfig::llama2_7b());
    rc.tp = 8;
    rc.devices = 32;
    Engine::new(rc)
}

fn main() {
    println!("==== scenario sweep: CompAir_Opt, llama2-7b, TP=8, 32 devices ====\n");
    for sc in Scenario::all() {
        let name = sc.name;
        let desc = sc.description;
        let n = sc.default_requests;
        let sr = engine(ArchKind::CompAirOpt).serve_scenario(sc, n, 42);
        println!("-- {name}: {desc} --");
        print!("{}", serving::render_summary(&sr.report));
        sr.report.class_table("per-class").print();
        println!();
    }

    println!("==== mixed multi-tenant blend across architectures ====");
    let mut t = Table::new(
        "same trace, same SLOs",
        &["arch", "makespan", "tok/s", "ttft p99", "slo%", "energy/tok"],
    );
    for arch in [ArchKind::Cent, ArchKind::CentCurry, ArchKind::CompAirOpt] {
        let sc = Scenario::by_name("mixed").unwrap();
        let r = engine(arch).serve_scenario(sc, 48, 42).report;
        t.rowv(vec![
            arch.label().to_string(),
            ftime_ns(r.makespan_ns as f64),
            fnum(r.throughput_tok_s),
            ftime_ns(r.ttft_p99_ns),
            format!("{:.1}%", r.slo_attainment * 100.0),
            fenergy_pj(r.energy_per_token_pj),
        ]);
    }
    t.print();
}
